"""SPx non-uniform quantization (the paper's §3.2, Eq. 3.1 / 3.3 / 3.4).

The paper generalizes Power-of-Two (PoT) quantization to *sums of x
power-of-two terms*:

    Q(b, alpha) = ±alpha * sum_i q_i,
    q_i in {0, ±1/2^(2^{b_i}-1), ±1/2^(2^{b_i}-2), ..., ±1/2},
    b = 1 (sign) + sum_i b_i.

x = 1 recovers PoT (Eq. 3.1); x = 2 recovers SP2 of Chang et al. (HPCA'21,
Eq. 3.3). Larger x buys resolution near the tail ends ±alpha where PoT's
levels collapse, at the cost of more shift-add terms on the FPGA — on TPU the
cost is a (slightly) larger codebook LUT, which is free in VMEM.

Everything in this module is pure level-set / codebook math, independent of
where the codes are used (weights, optimizer moments, gradient compression).
All quantize/dequantize functions are jit-traceable.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "pot_levels",
    "sp2_levels",
    "spx_levels",
    "uniform_levels",
    "codebook",
    "quantize_to_codes",
    "dequantize_codes",
    "quantize",
    "fake_quantize",
    "calibrate_minmax",
    "calibrate_mse",
    "pack_int4",
    "unpack_int4",
    "SCHEMES",
    "scheme_levels",
    "KV_CODE_BYTES",
    "KV_SCALE_BYTES",
    "kv_token_side_bytes",
]

#: Quantized KV-cache storage layout (scheme-independent; the single owner
#: of these constants — serving/kv_cache.py, runtime/planner.py and
#: benchmarks/roofline.py all derive their byte math from here): one uint8
#: code per element plus one f32 scale per (token, head) side.
KV_CODE_BYTES = 1
KV_SCALE_BYTES = 4


def kv_token_side_bytes(dh: int) -> int:
    """Bytes one token's K *or* V occupies for one KV head in the
    quantized codes+scale cache layout."""
    return dh * KV_CODE_BYTES + KV_SCALE_BYTES


# ---------------------------------------------------------------------------
# Level-set construction (numpy; done once per scheme, cached)
# ---------------------------------------------------------------------------

def _single_term_set(b_i: int) -> np.ndarray:
    """q_i in {0, ±1/2^(2^{b_i}-1), ..., ±1/2}  (paper Eq. 3.4, inner set)."""
    if b_i <= 0:
        return np.array([0.0])
    exps = np.arange(1, 2 ** b_i)          # 1 .. 2^{b_i}-1
    mags = 0.5 ** exps                     # 1/2 .. 1/2^(2^{b_i}-1)
    return np.concatenate([[0.0], mags, -mags])


@functools.lru_cache(maxsize=None)
def spx_levels(term_bits: tuple[int, ...]) -> np.ndarray:
    """Canonical SPx level set on [-1, 1] for the given per-term bit widths.

    Implements Eq. 3.4: levels are all distinct values of ±sum_i q_i. The
    overall sign bit is implied by the ± closure of the inner sets, and the
    result always contains ±max and 0. Returned sorted ascending.
    """
    acc = np.array([0.0])
    for b_i in term_bits:
        term = _single_term_set(int(b_i))
        acc = (acc[:, None] + term[None, :]).ravel()
    # ± closure (paper writes ±alpha * {sum}), dedupe on a fixed grid to kill
    # float fuzz (levels are dyadic rationals, exactly representable).
    acc = np.concatenate([acc, -acc])
    levels = np.unique(acc)
    # Normalize so the largest magnitude is exactly 1 (alpha carries scale).
    m = np.abs(levels).max()
    if m > 0:
        levels = levels / m
    return levels.astype(np.float64)


@functools.lru_cache(maxsize=None)
def pot_levels(b: int) -> np.ndarray:
    """Eq. 3.1: alpha * {0, ±1/2^(2^{b-1}-1), ..., ±1/2, ±1}."""
    exps = np.arange(0, 2 ** (b - 1))      # 0 .. 2^{b-1}-1
    mags = 0.5 ** exps                     # 1, 1/2, ..., 1/2^(2^{b-1}-1)
    levels = np.unique(np.concatenate([[0.0], mags, -mags]))
    return levels.astype(np.float64)


def sp2_levels(b: int) -> np.ndarray:
    """Eq. 3.3 with the balanced split b1 + b2 = b - 1 (Chang et al.)."""
    b1 = (b - 1 + 1) // 2
    b2 = (b - 1) - b1
    return spx_levels((b1, b2))


@functools.lru_cache(maxsize=None)
def uniform_levels(b: int) -> np.ndarray:
    """Symmetric uniform b-bit levels (the §3.2.A baseline)."""
    n = 2 ** (b - 1) - 1
    return (np.arange(-n, n + 1) / n).astype(np.float64)


#: Named schemes used across the framework. Values are (family, arg). Scheme
#: names carry the *code width* (bits to index the level set) — note Eq. 3.4's
#: b = sum(b_i) does not in general equal the code width because sums of PoT
#: terms collide; we name by what HBM actually stores.
SCHEMES = {
    "uniform8": ("uniform", 8),
    "uniform4": ("uniform", 4),
    "pot4": ("pot", 4),
    "pot3": ("pot", 3),
    "sp2_4": ("spx", (2, 1)),        # 4-bit SP2 (15 levels)
    "sp2_8": ("spx", (4, 2)),        # 8-bit SP2 (179 levels)
    "spx_5_x3": ("spx", (2, 2, 1)),  # 5-bit, x=3 terms — the paper's extension
    "spx_8_x3": ("spx", (3, 2, 2)),  # 8-bit, x=3 terms (131 levels)
}


def scheme_levels(scheme: str) -> np.ndarray:
    family, arg = SCHEMES[scheme]
    if family == "uniform":
        return uniform_levels(arg)
    if family == "pot":
        return pot_levels(arg)
    if family == "spx":
        return spx_levels(tuple(arg))
    raise ValueError(f"unknown scheme family {family!r}")


def code_width(levels: np.ndarray | Sequence[float]) -> int:
    """Bits needed to index the level set."""
    n = len(levels)
    return max(1, int(np.ceil(np.log2(n))))


# ---------------------------------------------------------------------------
# Codebook quantize / dequantize (jit-traceable)
# ---------------------------------------------------------------------------

def codebook(levels: np.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """Levels as a device LUT, padded to the next power of two so that codes
    fill the integer range (padding repeats the last level — harmless, those
    codes are never produced by quantize)."""
    n = len(levels)
    size = 2 ** code_width(levels)
    padded = np.concatenate([levels, np.full(size - n, levels[-1])])
    return jnp.asarray(padded, dtype=dtype)


def _midpoints(levels: np.ndarray) -> np.ndarray:
    return (levels[1:] + levels[:-1]) / 2.0


def quantize_to_codes(x: jax.Array, levels: np.ndarray, scale: jax.Array) -> jax.Array:
    """Nearest-level codes for x given per-channel `scale` (broadcastable).

    Nearest-neighbour on a sorted level set == searchsorted over midpoints.
    Returns uint8 codes (all schemes here are <= 8 bit).
    """
    mids = jnp.asarray(_midpoints(levels), dtype=jnp.float32)
    xn = (x / scale).astype(jnp.float32)
    xn = jnp.clip(xn, float(levels[0]), float(levels[-1]))
    codes = jnp.searchsorted(mids, xn, side="left")
    return codes.astype(jnp.uint8)


def dequantize_codes(codes: jax.Array, lut: jax.Array, scale: jax.Array,
                     dtype=jnp.bfloat16) -> jax.Array:
    """codes -> lut[codes] * scale. `lut` from `codebook()`."""
    vals = jnp.take(lut, codes.astype(jnp.int32), axis=0)
    return (vals * scale).astype(dtype)


def quantize(x: jax.Array, scheme: str, scale: jax.Array) -> jax.Array:
    return quantize_to_codes(x, scheme_levels(scheme), scale)


def fake_quantize(x: jax.Array, scheme: str, scale: jax.Array,
                  dtype=None) -> jax.Array:
    """Quantize-dequantize round trip (QAT / error-feedback building block)."""
    levels = scheme_levels(scheme)
    codes = quantize_to_codes(x, levels, scale)
    out = dequantize_codes(codes, codebook(levels), scale, dtype=jnp.float32)
    return out.astype(dtype or x.dtype)


# ---------------------------------------------------------------------------
# Calibration of alpha (per-channel scale)
# ---------------------------------------------------------------------------

def _reduce_axes(x: jax.Array, channel_axis: int | None,
                 axes: tuple | None = None):
    if axes is not None:
        return tuple(a % x.ndim for a in axes)
    if channel_axis is None:
        return tuple(range(x.ndim))
    channel_axis = channel_axis % x.ndim
    return tuple(a for a in range(x.ndim) if a != channel_axis)


def calibrate_minmax(x: jax.Array, channel_axis: int | None = -1,
                     axes: tuple | None = None) -> jax.Array:
    """alpha = max|x| per channel (keepdims, broadcastable against x).

    ``axes`` overrides ``channel_axis``: reduce exactly those axes (used for
    stacked expert/layer weights where only the contracting dim reduces)."""
    axes = _reduce_axes(x, channel_axis, axes)
    a = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    return jnp.maximum(a, 1e-12)


def calibrate_mse(x: jax.Array, scheme: str, channel_axis: int | None = -1,
                  num_grid: int = 24, lo: float = 0.4, hi: float = 1.05,
                  axes: tuple | None = None) -> jax.Array:
    """MSE-optimal alpha: sweep a grid of fractions of max|x| per channel and
    pick the scale minimizing quantization MSE. Cheap (done offline, once per
    weight), and markedly better than minmax for heavy-tailed weights — this
    is where SPx's tail resolution (the paper's selling point) actually shows.
    """
    levels = scheme_levels(scheme)
    lut = codebook(levels)
    base = calibrate_minmax(x, channel_axis, axes)
    fracs = np.linspace(lo, hi, num_grid)
    axes = _reduce_axes(x, channel_axis, axes)

    def err_for(frac):
        scale = base * frac
        codes = quantize_to_codes(x, levels, scale)
        xh = dequantize_codes(codes, lut, scale, dtype=jnp.float32)
        return jnp.sum((xh - x.astype(jnp.float32)) ** 2, axis=axes, keepdims=True)

    errs = jnp.stack([err_for(f) for f in fracs])          # (G, ...1s...)
    best = jnp.argmin(errs, axis=0)                        # broadcast shape
    fr = jnp.take(jnp.asarray(fracs, jnp.float32), best)
    return base * fr


# ---------------------------------------------------------------------------
# int4 packing (two codes per byte) — halves HBM traffic again for b<=4
# ---------------------------------------------------------------------------

def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack uint8 codes (<16) pairwise along the LAST axis: even idx -> low
    nibble. Last dim must be even."""
    if codes.shape[-1] % 2:
        raise ValueError(
            f"pack_int4 needs an even last dim (two 4-bit codes per byte); "
            f"got shape {tuple(codes.shape)} with last dim "
            f"{codes.shape[-1]}. Pad the weight or pass pack=False.")
    lo = codes[..., 0::2]
    hi = codes[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of pack_int4; doubles the last axis."""
    lo = packed & 0x0F
    hi = (packed >> 4) & 0x0F
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2).astype(jnp.uint8)
