"""Core: the paper's contribution — SPx non-uniform quantization and the
pipelined (load/compute-decoupled) quantized matmul primitive."""
from .spx import (SCHEMES, calibrate_minmax, calibrate_mse, codebook,
                  dequantize_codes, fake_quantize, pack_int4, pot_levels,
                  quantize, quantize_to_codes, scheme_levels, sp2_levels,
                  spx_levels, uniform_levels, unpack_int4)
from .quantized import QuantizedTensor, dequantize, quantize_weight, ref_matmul
from .pipeline import TPU_V5E, BlockPlan, HwSpec, plan_matmul_blocks

__all__ = [
    "SCHEMES", "QuantizedTensor", "TPU_V5E", "BlockPlan", "HwSpec",
    "calibrate_minmax", "calibrate_mse", "codebook", "dequantize",
    "dequantize_codes", "fake_quantize", "pack_int4", "plan_matmul_blocks",
    "pot_levels", "quantize", "quantize_to_codes", "quantize_weight",
    "ref_matmul", "scheme_levels", "sp2_levels", "spx_levels",
    "uniform_levels", "unpack_int4",
]
