"""QuantizedTensor: SPx-coded weight container used across the framework.

A QuantizedTensor stores a 2-D (or stacked 3-D+, for scanned layers / experts)
weight as:
  * ``codes``  — uint8 level indices (optionally two int4 codes packed/byte),
  * ``scale``  — per-output-channel alpha (float32, broadcastable),
  * a static codebook identified by ``scheme`` (LUT materialized on demand).

It is registered as a pytree so it flows through jit/pjit/scan like any other
parameter; the static metadata (scheme, packing, logical shape) lives in the
pytree aux data so tracing sees consistent structure.

The matmul entry point here is the *reference* path (pure jnp: LUT gather →
bf16 matmul). The Pallas TPU kernel with in-VMEM dequantization lives in
``repro.kernels`` and is selected by ``repro.kernels.ops.spx_matmul``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import spx

__all__ = ["QuantizedTensor", "quantize_weight", "dequantize", "ref_matmul"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    codes: jax.Array            # uint8; last dim possibly packed (int4)
    scale: jax.Array            # f32, broadcastable to logical shape
    scheme: str                 # key into spx.SCHEMES
    packed: bool                # True => two 4-bit codes per byte on last dim

    # -- pytree protocol ----------------------------------------------------
    # NOTE: the logical shape is *derived* from codes (not static aux data) so
    # that lax.scan / vmap can slice stacked QuantizedTensors (leading layer /
    # expert dims) without aux-data mismatches.
    def tree_flatten(self):
        return (self.codes, self.scale), (self.scheme, self.packed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale = children
        scheme, packed = aux
        return cls(codes, scale, scheme, packed)

    # -- convenience ---------------------------------------------------------
    @property
    def logical_shape(self):
        s = tuple(self.codes.shape)
        return s[:-1] + (s[-1] * 2,) if self.packed else s

    @property
    def shape(self):
        return self.logical_shape

    @property
    def ndim(self):
        return len(self.logical_shape)

    @property
    def lut(self) -> jnp.ndarray:
        return spx.codebook(spx.scheme_levels(self.scheme))

    @property
    def bits(self) -> int:
        return spx.code_width(spx.scheme_levels(self.scheme))

    def nbytes_stored(self) -> int:
        n = int(np.prod(self.logical_shape))
        per = 0.5 if self.packed else 1.0
        return int(n * per) + int(np.prod(self.scale.shape)) * 4

    def dequantize(self, dtype=jnp.bfloat16) -> jax.Array:
        return dequantize(self, dtype)


def quantize_weight(w: jax.Array, scheme: str = "sp2_4", *,
                    contract_axis: int = -2, calibration: str = "mse",
                    pack: bool | None = None) -> QuantizedTensor:
    """Quantize a weight tensor to SPx codes with per-channel calibration.

    Scale (alpha) reduces over ``contract_axis`` only, so it is per output
    channel for 2-D (K, N) weights and per-(expert/layer, channel) for
    stacked (E, K, N) weights.
    """
    axes = (contract_axis,)
    if calibration == "mse":
        scale = spx.calibrate_mse(w, scheme, axes=axes)
    elif calibration == "minmax":
        scale = spx.calibrate_minmax(w, axes=axes)
    else:
        raise ValueError(f"unknown calibration {calibration!r}")
    levels = spx.scheme_levels(scheme)
    codes = spx.quantize_to_codes(w, levels, scale)
    width = spx.code_width(levels)
    if pack is None:
        pack = width <= 4 and w.shape[-1] % 2 == 0
    if pack and width > 4:
        raise ValueError(f"cannot int4-pack a {width}-bit scheme {scheme!r}")
    if pack:
        codes = spx.pack_int4(codes)
    return QuantizedTensor(codes, scale.astype(jnp.float32), scheme, bool(pack))


def dequantize(qt: QuantizedTensor, dtype=jnp.bfloat16) -> jax.Array:
    codes = spx.unpack_int4(qt.codes) if qt.packed else qt.codes
    return spx.dequantize_codes(codes, qt.lut, qt.scale, dtype=dtype)


def ref_matmul(x: jax.Array, qt: QuantizedTensor, *,
               precision=None, out_dtype=None) -> jax.Array:
    """Reference quantized matmul: x @ dequant(qt). Contracts x's last dim
    with qt's second-to-last logical dim. Works for 2-D and stacked 3-D qt
    (leading dims broadcast/batched by caller)."""
    w = dequantize(qt, dtype=x.dtype)
    out = jax.lax.dot_general(
        x, w,
        dimension_numbers=(((x.ndim - 1,), (w.ndim - 2,)), ((), ())),
        precision=precision)
    return out.astype(out_dtype or x.dtype)
