"""Analytical model of the paper's §3.1 load/compute decoupling, on TPU terms.

The paper's input buffer decouples RAM→buffer loading (clk_inbuff) from PU
compute (clk_compute); the pipeline is sound iff loading stays ahead of
compute. On TPU the same condition governs the Pallas/Mosaic double-buffered
pipeline: for each grid step, the DMA of the *next* (activation, weight-code)
block must finish within the MXU time of the *current* block:

    t_load(block)    = bytes(block) / BW_hbm
    t_compute(block) = flops(block) / peak_flops

This module evaluates that inequality for candidate BlockSpec shapes and is
used (a) by the kernels to choose default block shapes, (b) by the benchmark
harness to report the "pipeline feasibility" margin the paper argues in prose
(300 ns load vs 500 ns compute → compute-bound, pipeline hides the load).

Quantization enters t_load directly: b-bit SPx codes shrink the weight-block
bytes by 16/b versus bf16, widening the pipeline margin — this is the paper's
two contributions composing.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["HwSpec", "TPU_V5E", "BlockPlan", "plan_matmul_blocks"]


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str
    peak_bf16_flops: float      # FLOP/s per chip
    hbm_bw: float               # bytes/s per chip
    ici_bw: float               # bytes/s per link
    vmem_bytes: int             # per-core VMEM
    mxu_dim: int = 128          # systolic tile


TPU_V5E = HwSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    vmem_bytes=128 * 1024 * 1024,
    mxu_dim=128,
)


@dataclasses.dataclass
class BlockPlan:
    bm: int
    bn: int
    bk: int
    weight_bits: int
    vmem_bytes: int             # working set incl. double buffers + acc
    t_load: float               # s, per grid step (next-block DMA)
    t_compute: float            # s, per grid step (MXU on current block)
    pipelined: bool             # t_load <= t_compute (paper's condition)
    arithmetic_intensity: float # flops / HBM byte for the whole matmul

    @property
    def margin(self) -> float:
        """compute/load ratio; >1 means the DMA is fully hidden."""
        return self.t_compute / max(self.t_load, 1e-30)


def _block_cost(m, n, k, bm, bn, bk, weight_bits, act_bytes, hw: HwSpec):
    # Per grid step we stream one activation tile (bm x bk) and one weight
    # tile (bk x bn) at `weight_bits`; the f32 accumulator (bm x bn) lives in
    # VMEM across the k-loop (written back once per (m, n) tile).
    load_bytes = bm * bk * act_bytes + bk * bn * weight_bits / 8
    flops = 2.0 * bm * bn * bk
    t_load = load_bytes / hw.hbm_bw
    t_compute = flops / hw.peak_bf16_flops
    # double-buffered inputs + accumulator + dequantized weight tile
    vmem = 2 * (bm * bk * act_bytes + bk * bn * weight_bits / 8) \
        + bm * bn * 4 + bk * bn * 2
    return load_bytes, flops, t_load, t_compute, int(vmem)


def plan_matmul_blocks(m: int, n: int, k: int, *, weight_bits: int = 16,
                       act_bytes: int = 2, hw: HwSpec = TPU_V5E,
                       candidates=(128, 256, 512, 1024, 2048),
                       candidates_m=None, candidates_n=None,
                       candidates_k=None,
                       vmem_fraction: float = 0.9) -> BlockPlan:
    """Pick (bm, bn, bk) maximizing pipeline margin subject to VMEM fit and
    MXU alignment. Deterministic, pure math — used by the block planner
    (repro.runtime.planner) and reported in the benchmarks.

    ``candidates_m/n/k`` restrict the search per dimension (the planner
    passes divisor-filtered lists so chosen blocks tile the problem
    exactly); each defaults to ``candidates``.
    """
    best = None
    for bm in (candidates_m if candidates_m is not None else candidates):
        if bm > max(m, hw.mxu_dim):
            continue
        for bn in (candidates_n if candidates_n is not None else candidates):
            if bn > max(n, hw.mxu_dim):
                continue
            for bk in (candidates_k if candidates_k is not None
                       else candidates):
                if bk > max(k, hw.mxu_dim):
                    continue
                load_b, flops, t_l, t_c, vmem = _block_cost(
                    m, n, k, bm, bn, bk, weight_bits, act_bytes, hw)
                if vmem > hw.vmem_bytes * vmem_fraction:
                    continue
                # whole-matmul arithmetic intensity at this blocking: the
                # activation tile re-streams once per n-block, weights once
                # per m-block.
                n_m, n_n, n_k = (math.ceil(m / bm), math.ceil(n / bn),
                                 math.ceil(k / bk))
                total_bytes = (n_n * m * k * act_bytes
                               + n_m * k * n * weight_bits / 8
                               + m * n * act_bytes)
                ai = (2.0 * m * n * k) / total_bytes
                plan = BlockPlan(bm, bn, bk, weight_bits, vmem, t_l, t_c,
                                 t_l <= t_c, ai)
                key = (plan.pipelined, plan.margin, -vmem)
                if best is None or key > (best.pipelined, best.margin,
                                          -best.vmem_bytes):
                    best = plan
    if best is None:  # tiny problem: single MXU tile
        load_b, flops, t_l, t_c, vmem = _block_cost(
            m, n, k, hw.mxu_dim, hw.mxu_dim, hw.mxu_dim, weight_bits,
            act_bytes, hw)
        best = BlockPlan(hw.mxu_dim, hw.mxu_dim, hw.mxu_dim, weight_bits,
                         vmem, t_l, t_c, t_l <= t_c, 2.0 * hw.mxu_dim / 3)
    return best
