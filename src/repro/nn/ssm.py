"""State-space / recurrent blocks: Mamba (Jamba's mixer) and xLSTM's
mLSTM + sLSTM. All are attention-free (O(1) state per token -> they carry
the ``long_500k`` shape), and their projection matmuls route through the
quantized dense path like every other linear.

Training uses lax.scan over the sequence (a While loop in HLO — its
elementwise body is <1% of layer FLOPs; see DESIGN.md §6 on cost
accounting). Decode is a single-step state update.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.runtime import Runtime

from .layers import (constrain_feature_sharded, dense_apply, dense_init,
                     opt_barrier)

__all__ = [
    "mamba_init", "mamba_apply", "mamba_decode_step", "mamba_init_state",
    "mamba_paged_step",
    "mlstm_init", "mlstm_apply", "mlstm_decode_step", "mlstm_init_state",
    "mlstm_paged_step",
    "slstm_init", "slstm_apply", "slstm_decode_step", "slstm_init_state",
    "slstm_paged_step",
]


# ===========================================================================
# Mamba (selective SSM, mamba-1 form used by Jamba)
# ===========================================================================

def mamba_init(key, d_model: int, *, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dt_rank: int | None = None,
               dtype=jnp.float32) -> dict:
    di = expand * d_model
    dt_rank = dt_rank or max(16, d_model // 16)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * di, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (d_conv, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * d_state, dtype=dtype),
        "dt_proj": {"w": jax.random.normal(ks[3], (dt_rank, di), dtype)
                    * dt_rank ** -0.5,
                    "b": jnp.log(jnp.exp(jnp.full((di,), 0.01)) - 1.0)
                    .astype(dtype)},
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32), (di, d_state))
        ).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], di, d_model, dtype=dtype),
    }


def _mamba_dims(p):
    d_conv, di = p["conv_w"].shape
    d_state = p["A_log"].shape[1]
    dt_rank = p["dt_proj"]["w"].shape[0]
    return di, d_state, d_conv, dt_rank


def mamba_init_state(p, batch: int, dtype=jnp.float32):
    di, d_state, d_conv, _ = _mamba_dims(p)
    return {"h": jnp.zeros((batch, di, d_state), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, di), dtype)}


def _causal_depthwise_conv(x, w, b):
    """x: (B, S, di); w: (dc, di). Causal, per-channel."""
    dc = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(dc):  # dc is 4: unrolled taps, no While loop
        out = out + pad[:, j:j + x.shape[1], :] * w[j]
    return out + b


SSM_CHUNK = 128


def _selective_scan(u, dt, A, Bm, Cm, D, h0, *, chunk: int = SSM_CHUNK,
                    unroll: bool = False):
    """Chunked selective scan. u, dt: (B,S,di); A: (di,ds); Bm/Cm: (B,S,ds);
    h0: (B,di,ds). Returns y (B,S,di), hT.

    The (B,S,di,ds) state tensor is never materialized for the full
    sequence: an outer lax.scan carries h across chunks of ``chunk`` steps;
    inside a chunk, an associative scan over (decay, input) pairs computes
    all within-chunk states in parallel form. Each chunk body is remat'd so
    the backward recomputes it — saved residuals are one (B,di,ds) carry
    per chunk instead of per step (the difference between 1GB and 68GB per
    device for Jamba's train_4k)."""
    b, s, di = u.shape
    ds = A.shape[1]
    c = min(chunk, s)
    if s % c:
        c = s
    nc = s // c

    def chunk_xs(x):
        return x.reshape(b, nc, c, *x.shape[2:]).swapaxes(0, 1)

    xs = (chunk_xs(u), chunk_xs(dt), chunk_xs(Bm), chunk_xs(Cm))

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_body(h, inp):
        inp = opt_barrier(inp)
        u_c, dt_c, B_c, C_c = inp                          # (B,c,di), (B,c,ds)
        # f32 only per chunk-slice — full-sequence (B,S,di) tensors stay in
        # the model's compute dtype (bf16 at production scale)
        u32 = u_c.astype(jnp.float32)
        dt32 = dt_c.astype(jnp.float32)
        dA = jnp.exp(dt32[..., None] * A)                  # (B,c,di,ds)
        dBu = dt32[..., None] * B_c.astype(jnp.float32)[:, :, None, :] \
            * u32[..., None]
        # h_t = dA_t h_{t-1} + dBu_t  via associative composition
        # (A2, b2) o (A1, b1) = (A2*A1, A2*b1 + b2), scanned along c
        def compose(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a2 * a1, a2 * b1 + b2
        acc_a, acc_b = jax.lax.associative_scan(compose, (dA, dBu), axis=1)
        hs = acc_a * h[:, None] + acc_b                    # (B,c,di,ds)
        y_c = jnp.einsum("bcds,bcs->bcd", hs,
                         C_c.astype(jnp.float32))
        return hs[:, -1], y_c.astype(u_c.dtype)

    hT, ys = jax.lax.scan(chunk_body, h0, xs,
                          unroll=True if unroll else 1)
    y = ys.swapaxes(0, 1).reshape(b, s, di) \
        + u * D.astype(u.dtype)
    return y, hT


def mamba_apply(p: dict, x: jax.Array, *, rt: Runtime,
                state: dict | None = None, return_state: bool = False):
    """x: (B, S, D) -> (B, S, D). Train/prefill form (scan over S)."""
    b, s, _ = x.shape
    di, d_state, d_conv, dt_rank = _mamba_dims(p)
    xz = constrain_feature_sharded(dense_apply(p["in_proj"], x, rt), rt)
    u_pre, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(_causal_depthwise_conv(u_pre, p["conv_w"], p["conv_b"]))
    u = constrain_feature_sharded(u, rt)
    proj = dense_apply(p["x_proj"], u, rt)
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    # dt stays in compute dtype for the full sequence; f32 happens per-chunk
    dt = jax.nn.softplus(dense_apply(p["dt_proj"], dt, rt)
                         .astype(jnp.float32)).astype(x.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    h0 = (state["h"] if state is not None
          else jnp.zeros((b, di, d_state), jnp.float32))
    y, hT = _selective_scan(u, dt, A, Bm, Cm,
                            p["D"].astype(jnp.float32), h0,
                            unroll=rt.unroll)
    out = dense_apply(p["out_proj"], (y.astype(x.dtype) * jax.nn.silu(z)), rt)
    if return_state:
        new_state = {"h": hT,
                     "conv": jax.lax.dynamic_slice_in_dim(
                         jnp.pad(u_pre, ((0, 0), (d_conv - 1, 0), (0, 0))),
                         s, d_conv - 1, axis=1).astype(x.dtype)}
        return out, new_state
    return out


def mamba_decode_step(p: dict, x: jax.Array, state: dict, *, rt: Runtime):
    """x: (B, 1, D); state: {'h': (B,di,ds), 'conv': (B,dc-1,di)}."""
    b = x.shape[0]
    di, d_state, d_conv, dt_rank = _mamba_dims(p)
    xz = dense_apply(p["in_proj"], x, rt)
    u, z = jnp.split(xz, 2, axis=-1)                       # (B,1,di)
    window = jnp.concatenate([state["conv"], u.astype(state["conv"].dtype)],
                             axis=1)                       # (B,dc,di)
    u_c = jnp.einsum("bcd,cd->bd", window.astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    u_c = jax.nn.silu(u_c)[:, None, :]                     # (B,1,di)
    proj = dense_apply(p["x_proj"], u_c.astype(x.dtype), rt)
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dense_apply(p["dt_proj"], dt, rt).astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0, :, None] * A)                    # (B,di,ds)
    dBu = dt[:, 0, :, None] * Bm[:, 0, None, :] * u_c[:, 0, :, None]
    h = dA * state["h"] + dBu
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0].astype(jnp.float32))
    y = y + u_c[:, 0].astype(jnp.float32) * p["D"]
    out = dense_apply(p["out_proj"],
                      (y[:, None, :].astype(x.dtype) * jax.nn.silu(z)), rt)
    new_state = {"h": h, "conv": window[:, 1:, :]}
    return out, new_state


def _paged_conv(state_conv, u_pre, n_valid, conv_w, conv_b):
    """Causal depthwise conv over a ragged C-token chunk, continuing from
    a cached left-context window (the slab ``conv`` leaf).

    ``state_conv``: (B, dc-1, di) — pre-activations of the last dc-1
    tokens before this chunk; ``u_pre``: (B, C, di); ``n_valid``: (B,)
    int32 in [0, C]. Returns ``(u_c, new_conv)``: f32 conv pre-silu
    outputs for every chunk position (invalid positions produce garbage
    the caller masks/ignores) and the window advanced to end exactly at
    each row's last *valid* token — a row with ``n_valid == 0`` gets its
    window back unchanged."""
    b, c, di = u_pre.shape
    dcm1 = state_conv.shape[1]
    window = jnp.concatenate([state_conv, u_pre.astype(state_conv.dtype)],
                             axis=1)                   # (B, dc-1+C, di)
    w32 = conv_w.astype(jnp.float32)
    win32 = window.astype(jnp.float32)
    out = jnp.zeros((b, c, di), jnp.float32)
    for j in range(dcm1 + 1):   # dc taps (dc is 4): unrolled, no While
        out = out + win32[:, j:j + c, :] * w32[j]
    out = out + conv_b.astype(jnp.float32)
    idx = n_valid[:, None].astype(jnp.int32) \
        + jnp.arange(dcm1, dtype=jnp.int32)[None, :]   # (B, dc-1)
    new_conv = jnp.take_along_axis(window, idx[..., None], axis=1)
    return out, new_conv


def mamba_paged_step(p: dict, x: jax.Array, state: dict, n_valid, *,
                     rt: Runtime):
    """Slab-backed ragged chunk step: x (B, C, D) with ``n_valid`` (B,)
    valid tokens per row, state gathered from the StateCache slab region
    ({'h': (B,di,ds) f32, 'conv': (B,dc-1,di)}).

    Invalid positions are identity-masked (dt forced to 0 => dA = 1,
    dBu = 0), so the returned state equals running only each row's valid
    prefix — a fully inactive row (n_valid == 0) returns its state bit
    exact. Chaining C=1 steps matches ``mamba_decode_step`` and the
    ``mamba_apply`` full scan (regression-tested)."""
    b, c, _ = x.shape
    di, d_state, d_conv, dt_rank = _mamba_dims(p)
    xz = dense_apply(p["in_proj"], x, rt)
    u_pre, z = jnp.split(xz, 2, axis=-1)                   # (B,C,di)
    u_c, new_conv = _paged_conv(state["conv"], u_pre, n_valid,
                                p["conv_w"], p["conv_b"])
    u = jax.nn.silu(u_c).astype(x.dtype)                   # (B,C,di)
    proj = dense_apply(p["x_proj"], u, rt)
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dense_apply(p["dt_proj"], dt, rt)
                         .astype(jnp.float32)).astype(x.dtype)
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] \
        < n_valid[:, None].astype(jnp.int32)               # (B,C)
    dt = jnp.where(valid[..., None], dt, jnp.zeros_like(dt))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, hT = _selective_scan(u, dt, A, Bm, Cm,
                            p["D"].astype(jnp.float32), state["h"],
                            unroll=rt.unroll)
    out = dense_apply(p["out_proj"], (y.astype(x.dtype) * jax.nn.silu(z)),
                      rt)
    return out, {"h": hT, "conv": new_conv}


# ===========================================================================
# mLSTM (xLSTM's matrix-memory block, stabilized exponential gating)
# ===========================================================================

def mlstm_init(key, d_model: int, *, n_heads: int = 4, expand: int = 2,
               d_conv: int = 4, dtype=jnp.float32) -> dict:
    di = expand * d_model
    ks = jax.random.split(key, 7)
    s = di ** -0.5
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * di, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (d_conv, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], di, di, dtype=dtype),
        "wk": dense_init(ks[3], di, di, dtype=dtype),
        "wv": dense_init(ks[4], di, di, dtype=dtype),
        "w_gates": dense_init(ks[5], di, 2 * n_heads, dtype=dtype),
        "out_norm_g": jnp.ones((di,), dtype),
        "down_proj": dense_init(ks[6], di, d_model, dtype=dtype),
    }


def _mlstm_dims(p, n_heads):
    w = p["wq"]["w"]
    di = w.logical_shape[0] if hasattr(w, "logical_shape") else w.shape[0]
    return di, n_heads, di // n_heads


def mlstm_init_state(p, batch: int, dtype=jnp.float32, *, n_heads: int = 4):
    di, nh, dh = _mlstm_dims(p, n_heads)
    d_conv = p["conv_w"].shape[0]
    return {"C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.zeros((batch, nh), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, di), dtype)}


def _mlstm_qkv_gates(p, u, rt, n_heads):
    """u: (B,S,di) -> q,k,v (B,S,NH,dh), i/f gate preacts (B,S,NH)."""
    b, s, di = u.shape
    _, nh, dh = _mlstm_dims(p, n_heads)
    q = dense_apply(p["wq"], u, rt).reshape(b, s, nh, dh)
    k = dense_apply(p["wk"], u, rt).reshape(b, s, nh, dh) * (dh ** -0.5)
    v = dense_apply(p["wv"], u, rt).reshape(b, s, nh, dh)
    gates = dense_apply(p["w_gates"], u, rt).astype(jnp.float32)
    ig, fg = jnp.split(gates, 2, axis=-1)                  # (B,S,NH)
    return q, k, v, ig, fg


def _mlstm_cell(C, n, m, q, k, v, ig, fg):
    """Single stabilized mLSTM step. C:(B,NH,dh,dh) n:(B,NH,dh) m:(B,NH);
    q,k,v:(B,NH,dh); ig,fg:(B,NH)."""
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + m, ig)
    fs = jnp.exp(logf + m - m_new)[..., None]              # (B,NH,1)
    is_ = jnp.exp(ig - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = fs[..., None] * C + is_[..., None] * vf[..., :, None] * kf[..., None, :]
    n = fs * n + is_ * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", C, qf)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf))
    den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return C, n, m_new, num / den


def _mlstm_chunkwise(q, k, v, ig, fg, C0, n0, m0, *, chunk: int = 128,
                     unroll: bool = False):
    """Chunkwise-parallel stabilized mLSTM (linear-attention form).

    q,k,v: (B,S,NH,dh); ig,fg: (B,S,NH); states C0 (B,NH,dh,dh),
    n0 (B,NH,dh), m0 (B,NH). Returns (h (B,S,NH,dh), C, n, m).

    Per-step recurrence (see _mlstm_cell) unrolls within a chunk to
      m_t = F_t + max(m0, G_t),  F_t = cumsum(logf),  G_t = cummax(logi-F)
      h_num_t = e^{F_t+m0-m_t} C0 q_t + sum_j [e^{logi_j-F_j+F_t-m_t}
                                               (q_t.k_j)] v_j   (j<=t)
    so a chunk costs one (c, c) masked score matrix per head — the
    (B,S,NH,dh,dh) per-step state tensor never exists. The chunk boundary
    state update is one einsum. Chunk bodies are remat'd: saved residuals
    are nc matrix states instead of S of them."""
    b, s, nh, dh = q.shape
    c = min(chunk, s)
    if s % c:
        c = s
    nc = s // c

    def cx(t):  # (B,S,...) -> (nc, B, c, ...)
        return t.reshape(b, nc, c, *t.shape[2:]).swapaxes(0, 1)

    xs = tuple(map(cx, (q, k, v, ig.astype(jnp.float32),
                        fg.astype(jnp.float32))))

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_body(carry, inp):
        inp = opt_barrier(inp)
        C0c, n0c, m0c = carry                    # (B,NH,dh,dh),(B,NH,dh),(B,NH)
        q_c, k_c, v_c, ig_c, fg_c = inp          # (B,c,NH,*)
        logf = jax.nn.log_sigmoid(fg_c)          # (B,c,NH)
        F = jnp.cumsum(logf, axis=1)             # inclusive
        G = jax.lax.cummax(ig_c - F, axis=1)
        m = F + jnp.maximum(m0c[:, None], G)     # (B,c,NH)
        qf = q_c.astype(jnp.float32)
        kf = k_c.astype(jnp.float32)
        vf = v_c.astype(jnp.float32)
        # inter-chunk: e^{F+m0-m} (C0 q_t), (n0.q_t)
        scale0 = jnp.exp(F + m0c[:, None] - m)   # (B,c,NH)
        num0 = jnp.einsum("bhvk,bchk->bchv", C0c, qf) * scale0[..., None]
        den0 = jnp.einsum("bhk,bchk->bch", n0c, qf) * scale0
        # intra-chunk scores: w_tj = e^{logi_j - F_j + F_t - m_t}, j<=t.
        # Mask the exponent (not the result): for j>t it grows like
        # F_t - F_j ~ 0.7*(j-t), which overflows exp at c>=128 and would
        # turn the masked product into inf*0 = NaN.
        a_j = (ig_c - F)                          # (B,c,NH) at index j
        expo = a_j[:, None, :, :] + (F - m)[:, :, None, :]        # (B,t,j,NH)
        causal = jnp.tril(jnp.ones((c, c), jnp.bool_))
        w = jnp.exp(jnp.where(causal[None, :, :, None], expo, -jnp.inf))
        s_qk = jnp.einsum("bthk,bjhk->btjh", qf, kf)
        sw = s_qk * w
        num = num0 + jnp.einsum("btjh,bjhv->bthv", sw, vf)
        # n_t.q_t = (n0.q_t) e^{...} + sum_j w_tj (k_j.q_t) = den0 + sum_j sw
        den = den0 + jnp.sum(sw, axis=2)
        h = num / jnp.maximum(jnp.abs(den)[..., None], jnp.exp(-m)[..., None])
        # chunk-end state
        F_c = F[:, -1]                            # (B,NH)
        m_c = m[:, -1]
        sc_state = jnp.exp(ig_c - F + F_c[:, None] - m_c[:, None])  # (B,c,NH)
        C = jnp.exp(F_c + m0c - m_c)[..., None, None] * C0c \
            + jnp.einsum("bch,bchv,bchk->bhvk", sc_state, vf, kf)
        n = jnp.exp(F_c + m0c - m_c)[..., None] * n0c \
            + jnp.einsum("bch,bchk->bhk", sc_state, kf)
        return (C, n, m_c), h

    (C, n, m), hs = jax.lax.scan(chunk_body, (C0, n0, m0), xs,
                                 unroll=True if unroll else 1)
    h = hs.swapaxes(0, 1).reshape(b, s, nh, dh)
    return h, C, n, m


def mlstm_apply(p: dict, x: jax.Array, *, rt: Runtime, n_heads: int = 4,
                state: dict | None = None, return_state: bool = False):
    """x: (B, S, D) -> (B, S, D)."""
    b, s, _ = x.shape
    di, nh, dh = _mlstm_dims(p, n_heads)
    xz = constrain_feature_sharded(dense_apply(p["in_proj"], x, rt), rt)
    u_pre, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(_causal_depthwise_conv(u_pre, p["conv_w"], p["conv_b"]))
    u = constrain_feature_sharded(u, rt)
    q, k, v, ig, fg = _mlstm_qkv_gates(p, u, rt, nh)
    st = state or mlstm_init_state(p, b, x.dtype, n_heads=nh)

    hs4, C, n, m = _mlstm_chunkwise(q, k, v, ig, fg, st["C"], st["n"],
                                    st["m"], unroll=rt.unroll)
    h = hs4.reshape(b, s, di).astype(x.dtype)
    # per-head groupnorm-ish output norm (rms over head dim)
    hn = h.reshape(b, s, nh, dh)
    hn = hn * jax.lax.rsqrt(jnp.mean(hn.astype(jnp.float32) ** 2, axis=-1,
                                     keepdims=True) + 1e-6).astype(x.dtype)
    h = hn.reshape(b, s, di) * p["out_norm_g"].astype(x.dtype)
    out = dense_apply(p["down_proj"], h * jax.nn.silu(z), rt)
    if return_state:
        d_conv = p["conv_w"].shape[0]
        conv = jax.lax.dynamic_slice_in_dim(
            jnp.pad(u_pre, ((0, 0), (d_conv - 1, 0), (0, 0))), s, d_conv - 1,
            axis=1).astype(x.dtype)
        return out, {"C": C, "n": n, "m": m, "conv": conv}
    return out


def mlstm_decode_step(p: dict, x: jax.Array, state: dict, *, rt: Runtime,
                      n_heads: int = 4):
    b = x.shape[0]
    di, nh, dh = _mlstm_dims(p, n_heads)
    xz = dense_apply(p["in_proj"], x, rt)
    u, z = jnp.split(xz, 2, axis=-1)                       # (B,1,di)
    window = jnp.concatenate([state["conv"], u.astype(state["conv"].dtype)],
                             axis=1)
    u_c = jnp.einsum("bcd,cd->bd", window.astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    u_c = jax.nn.silu(u_c)[:, None, :].astype(x.dtype)
    q, k, v, ig, fg = _mlstm_qkv_gates(p, u_c, rt, nh)
    C, n, m, h = _mlstm_cell(state["C"], state["n"], state["m"],
                             q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0])
    h = h.reshape(b, 1, di).astype(x.dtype)
    hn = h.reshape(b, 1, nh, dh)
    hn = hn * jax.lax.rsqrt(jnp.mean(hn.astype(jnp.float32) ** 2, axis=-1,
                                     keepdims=True) + 1e-6).astype(x.dtype)
    h = hn.reshape(b, 1, di) * p["out_norm_g"].astype(x.dtype)
    out = dense_apply(p["down_proj"], h * jax.nn.silu(z), rt)
    return out, {"C": C, "n": n, "m": m, "conv": window[:, 1:, :]}


def mlstm_paged_step(p: dict, x: jax.Array, state: dict, n_valid, *,
                     rt: Runtime, n_heads: int = 4):
    """Slab-backed ragged chunk step for mLSTM: x (B, C, D), ``n_valid``
    (B,) valid tokens per row, state from the slab region.

    Invalid positions are identity-masked through the gates: fg forced to
    +1e9 (log_sigmoid -> exactly 0.0 in f32, decay 1) and ig to -1e30
    (zero contribution), so ``_mlstm_chunkwise`` carries (C, n, m) across
    them untouched and each row's returned state equals running only its
    valid prefix."""
    b, c, _ = x.shape
    di, nh, dh = _mlstm_dims(p, n_heads)
    xz = dense_apply(p["in_proj"], x, rt)
    u_pre, z = jnp.split(xz, 2, axis=-1)                   # (B,C,di)
    u_c, new_conv = _paged_conv(state["conv"], u_pre, n_valid,
                                p["conv_w"], p["conv_b"])
    u = jax.nn.silu(u_c).astype(x.dtype)
    q, k, v, ig, fg = _mlstm_qkv_gates(p, u, rt, nh)       # gates (B,C,NH)
    valid = (jnp.arange(c, dtype=jnp.int32)[None, :]
             < n_valid[:, None].astype(jnp.int32))[..., None]
    fg = jnp.where(valid, fg, jnp.float32(1e9))
    ig = jnp.where(valid, ig, jnp.float32(-1e30))
    h4, C_, n_, m_ = _mlstm_chunkwise(q, k, v, ig, fg, state["C"],
                                      state["n"], state["m"],
                                      unroll=rt.unroll)
    h = h4.reshape(b, c, di).astype(x.dtype)
    hn = h.reshape(b, c, nh, dh)
    hn = hn * jax.lax.rsqrt(jnp.mean(hn.astype(jnp.float32) ** 2, axis=-1,
                                     keepdims=True) + 1e-6).astype(x.dtype)
    h = hn.reshape(b, c, di) * p["out_norm_g"].astype(x.dtype)
    out = dense_apply(p["down_proj"], h * jax.nn.silu(z), rt)
    return out, {"C": C_, "n": n_, "m": m_, "conv": new_conv}


# ===========================================================================
# sLSTM (scalar-memory xLSTM block, block-diagonal recurrence)
# ===========================================================================

def slstm_init(key, d_model: int, *, n_heads: int = 4, dtype=jnp.float32) -> dict:
    dh = d_model // n_heads
    ks = jax.random.split(key, 3)
    return {
        # input projections for (z, i, f, o) stacked: (D, 4D)
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype=dtype),
        # block-diagonal recurrent weights per gate: (4, NH, dh, dh)
        "r": jax.random.normal(ks[1], (4, n_heads, dh, dh), dtype)
             * (dh ** -0.5),
        "out_proj": dense_init(ks[2], d_model, d_model, dtype=dtype),
    }


def slstm_init_state(p, batch: int, dtype=jnp.float32):
    four, nh, dh, _ = p["r"].shape
    return {k: jnp.zeros((batch, nh, dh), jnp.float32)
            for k in ("c", "n", "h")} | \
           {"m": jnp.zeros((batch, nh, dh), jnp.float32)}


def _slstm_cell(p, carry, x_t):
    """x_t: (B, 4D) preactivations from input; carry dicts (B,NH,dh)."""
    four, nh, dh = p["r"].shape[0], p["r"].shape[1], p["r"].shape[2]
    b = x_t.shape[0]
    c, n, m, h = carry["c"], carry["n"], carry["m"], carry["h"]
    rec = jnp.einsum("ghij,bhj->bghi", p["r"].astype(jnp.float32), h)
    pre = x_t.reshape(b, 4, nh, dh).astype(jnp.float32) + rec
    z_t = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1]
    f_t = pre[:, 2]
    o_t = jax.nn.sigmoid(pre[:, 3])
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    fs = jnp.exp(logf + m - m_new)
    is_ = jnp.exp(i_t - m_new)
    c = fs * c + is_ * z_t
    n = fs * n + is_
    h_new = o_t * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "m": m_new, "h": h_new}


def slstm_apply(p: dict, x: jax.Array, *, rt: Runtime,
                state: dict | None = None, return_state: bool = False):
    b, s, d = x.shape
    nh, dh = p["r"].shape[1], p["r"].shape[2]
    pre = dense_apply(p["w_in"], x, rt)                    # (B,S,4D)
    st = state or slstm_init_state(p, b, x.dtype)

    def step(carry, x_t):
        carry = _slstm_cell(p, carry, x_t)
        return carry, carry["h"]

    # (sequential by nature; per-step state ~ (B,NH,dh) — cheap). Not
    # unrolled even for cost variants: 4096 unrolled elementwise steps would
    # explode HLO for <0.5% of layer FLOPs (documented in DESIGN.md §6).
    carry, hs = jax.lax.scan(step, st, jnp.swapaxes(pre, 0, 1))
    h = jnp.swapaxes(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    out = dense_apply(p["out_proj"], h, rt)
    if return_state:
        return out, carry
    return out


def slstm_decode_step(p: dict, x: jax.Array, state: dict, *, rt: Runtime):
    b, _, d = x.shape
    pre = dense_apply(p["w_in"], x, rt)[:, 0]              # (B,4D)
    carry = _slstm_cell(p, state, pre)
    h = carry["h"].reshape(b, 1, d).astype(x.dtype)
    return dense_apply(p["out_proj"], h, rt), carry


def slstm_paged_step(p: dict, x: jax.Array, state: dict, n_valid, *,
                     rt: Runtime):
    """Slab-backed ragged chunk step for sLSTM: x (B, C, D), ``n_valid``
    (B,) valid tokens per row. The recurrence is inherently sequential, so
    the chunk scans per token with a per-row masked carry: rows past
    their valid length keep the previous state bit exact (the cell still
    computes, the ``where`` discards it)."""
    b, c, d = x.shape
    pre = dense_apply(p["w_in"], x, rt)                    # (B,C,4D)
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] \
        < n_valid[:, None].astype(jnp.int32)               # (B,C)

    def step(carry, inp):
        x_t, v_t = inp                                     # (B,4D), (B,)
        new = _slstm_cell(p, carry, x_t)
        keep = v_t[:, None, None]
        carry = {k: jnp.where(keep, new[k], carry[k]) for k in carry}
        return carry, carry["h"]

    carry, hs = jax.lax.scan(step, state,
                             (jnp.swapaxes(pre, 0, 1),
                              jnp.swapaxes(valid, 0, 1)))
    h = jnp.swapaxes(hs, 0, 1).reshape(b, c, d).astype(x.dtype)
    return dense_apply(p["out_proj"], h, rt), carry
