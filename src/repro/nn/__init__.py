from .layers import (dense_apply, dense_init, embedding_apply,
                     embedding_init, layernorm_apply, layernorm_init,
                     norm_apply, norm_init, param_count, quantize_params,
                     rmsnorm_apply, rmsnorm_init)
from .mlp import mlp_apply, mlp_init
from .moe import moe_apply, moe_init
from .rotary import apply_mrope, apply_rope
from .transformer import (slot_init_cache, slot_init_paged_cache,
                          stack_apply, stack_decode, stack_init,
                          stack_paged, stack_prefill)

__all__ = [
    "apply_mrope", "apply_rope", "dense_apply", "dense_init",
    "embedding_apply", "embedding_init", "layernorm_apply", "layernorm_init",
    "mlp_apply", "mlp_init", "moe_apply", "moe_init", "norm_apply",
    "norm_init", "param_count", "quantize_params", "rmsnorm_apply",
    "rmsnorm_init", "slot_init_cache", "slot_init_paged_cache",
    "stack_apply", "stack_decode", "stack_init", "stack_paged",
    "stack_prefill",
]
