"""Feed-forward variants used across the assigned architectures:
  * "mlp"     — plain up/act/down (whisper: gelu; paper MLP: sigmoid)
  * "swiglu"  — gated silu (granite, qwen2.5, kimi/olmoe/jamba experts)
  * "geglu"   — gated gelu (gemma)
  * "relu2"   — squared relu, ungated (minitron/nemotron)
All large projections may be SPx-quantized (QuantizedTensor weights)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.runtime import Runtime

from .layers import dense_apply, dense_init

__all__ = ["mlp_init", "mlp_apply", "ACTIVATIONS"]

ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
}

_GATED = {"swiglu": "silu", "geglu": "gelu"}


def mlp_init(key, d_model: int, d_ff: int, *, variant: str = "swiglu",
             act: str = "gelu", bias: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, bias=bias, dtype=dtype),
         "down": dense_init(ks[1], d_ff, d_model, bias=bias, dtype=dtype)}
    if variant in _GATED:
        p["gate"] = dense_init(ks[2], d_model, d_ff, bias=bias, dtype=dtype)
    return p


def mlp_apply(p: dict, x: jax.Array, *, variant: str = "swiglu",
              act: str = "gelu", rt: Runtime | None = None) -> jax.Array:
    up = dense_apply(p["up"], x, rt)
    if variant in _GATED:
        g = dense_apply(p["gate"], x, rt)
        h = ACTIVATIONS[_GATED[variant]](g) * up
    else:
        h = ACTIVATIONS[act](up)
    return dense_apply(p["down"], h, rt)
