"""Minimal functional module substrate: params are plain pytrees (nested
dicts of arrays and/or QuantizedTensors); every module is an init fn plus an
apply fn. No framework dependency — jit/pjit/scan compose directly.

Any 2-D+ weight may be a ``QuantizedTensor`` (the paper's SPx codes) instead
of a dense array; ``dense_apply`` transparently routes through the pipelined
quantized matmul (`repro.kernels.ops.spx_matmul`).
"""
from __future__ import annotations

from typing import Any, TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.core.quantized import QuantizedTensor
from repro.kernels import ops

if TYPE_CHECKING:                 # annotations only — import Runtime from
    from repro.runtime import Runtime   # repro.runtime, not this module

__all__ = [
    "dense_init", "dense_apply", "embedding_init",
    "embedding_apply", "rmsnorm_init", "rmsnorm_apply", "layernorm_init",
    "layernorm_apply", "norm_init", "norm_apply", "quantize_params",
    "param_count", "opt_barrier",
]


@jax.custom_vjp
def opt_barrier(x):
    """optimization_barrier with an identity gradient. This jax version has
    no differentiation rule for the barrier primitive; its job here (block
    f32-convert fusion into residual-stack / checkpoint saves) is a
    forward-pass layout concern, so the backward passes cotangents through
    untouched. Accepts pytrees."""
    return jax.lax.optimization_barrier(x)


opt_barrier.defvjp(lambda x: (jax.lax.optimization_barrier(x), None),
                   lambda _, g: (g,))


# ---------------------------------------------------------------------------
# Dense / Embedding / Norms
# ---------------------------------------------------------------------------

def constrain_feature_sharded(x: jax.Array, rt: "Runtime | None"):
    """Constrain a (B, S, F) activation to shard F over the model axis
    (batch over data). Used inside SSM mixers where every op is pointwise
    over F — keeps GSPMD from propagating sequence sharding into the causal
    conv (whose halo forces a full-sequence all-gather)."""
    if rt is None or rt.mesh is None or x.ndim != 3:
        return x
    n_model = dict(rt.mesh.shape).get(rt.model_axis, 1)
    if x.shape[-1] % n_model:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = rt.data_axes if rt.data_axes else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rt.mesh, P(dp, None, rt.model_axis)))


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: dict, x: jax.Array, rt: Runtime | None = None) -> jax.Array:
    w = p["w"]
    if isinstance(w, QuantizedTensor):
        y = ops.spx_matmul(x, w, impl=(rt.impl if rt else "auto"))
    else:
        y = jnp.dot(x, w.astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def embedding_init(key, vocab: int, d_model: int, *, dtype=jnp.float32) -> dict:
    return {"table": jax.random.normal(key, (vocab, d_model), dtype)
            * (d_model ** -0.5)}


def embedding_apply(p: dict, ids: jax.Array) -> jax.Array:
    return jnp.take(p["table"], ids, axis=0)


def embedding_attend(p: dict, x: jax.Array) -> jax.Array:
    """Tied readout: x @ table^T."""
    t = p["table"]
    return jnp.dot(x, t.astype(x.dtype).T)


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    # statistics accumulate in f32 (einsum with preferred f32) but x itself
    # is never materialized in f32: an upcast here gets fused by XLA into
    # the *collectives* feeding the norm, doubling SP all-gather bytes
    # (§Perf iteration 5 in EXPERIMENTS.md)
    d = x.shape[-1]
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    scale = jax.lax.rsqrt(ss / d + eps)[..., None].astype(x.dtype)
    return x * scale * p["g"].astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm_apply(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    d = x.shape[-1]
    one = jnp.ones((d,), x.dtype)
    s1 = jnp.einsum("...d,d->...", x, one,
                    preferred_element_type=jnp.float32)
    s2 = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    mu = s1 / d
    var = jnp.maximum(s2 / d - mu * mu, 0.0)
    scale = jax.lax.rsqrt(var + eps)
    out = (x - mu[..., None].astype(x.dtype)) \
        * scale[..., None].astype(x.dtype)
    return out * p["g"].astype(x.dtype) + p["b"].astype(x.dtype)


def norm_init(kind: str, d: int, dtype=jnp.float32) -> dict:
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind: str, p: dict, x: jax.Array) -> jax.Array:
    return rmsnorm_apply(p, x) if kind == "rmsnorm" else layernorm_apply(p, x)


# ---------------------------------------------------------------------------
# Whole-tree helpers
# ---------------------------------------------------------------------------

#: sensitive / non-matmul params kept dense: embeddings, biases, norm gains,
#: router, SSM dynamics (A_log, D, dt, convs), sLSTM recurrence (r), head
_NO_QUANT_KEYS = ("table", "b", "g", "router", "A_log", "dt", "D",
                  "conv_b", "conv_w", "head", "out_norm_g", "r")


def quantize_params(params: Any, scheme: str = "sp2_4", *,
                    min_size: int = 4096, calibration: str = "mse") -> Any:
    """Replace every >=2-D weight leaf with >= ``min_size`` elements by its
    SPx QuantizedTensor (per-output-channel alpha). Norm gains, biases,
    embedding tables, routers, SSM dynamics params and small tensors stay
    dense. This is the paper's deployment step."""
    from repro.core.quantized import quantize_weight

    def maybe_q(path, leaf):
        if isinstance(leaf, QuantizedTensor):
            return leaf
        keys = {getattr(k, "key", getattr(k, "name", None)) for k in path}
        if keys & set(_NO_QUANT_KEYS):
            return leaf
        if (hasattr(leaf, "ndim") and leaf.ndim >= 2
                and leaf.size >= min_size
                and jnp.issubdtype(leaf.dtype, jnp.floating)
                and leaf.shape[-1] % 2 == 0):
            return quantize_weight(leaf, scheme, calibration=calibration)
        return leaf

    return jax.tree_util.tree_map_with_path(maybe_q, params)


def param_count(params: Any) -> int:
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda l: isinstance(l, QuantizedTensor))
    total = 0
    for l in leaves:
        if isinstance(l, QuantizedTensor):
            total += int(jnp.prod(jnp.array(l.logical_shape)))
        else:
            total += l.size
    return total
