"""Expert-parallel Mixture-of-Experts (top-k, capacity-bounded) via shard_map.

Design (DESIGN.md §4): experts shard over the ``model`` axis (expert
parallelism — all assigned expert counts 384/64/16 divide 16), tokens shard
over the data axes. Each (data, model) shard routes its local tokens against
the FULL router (replicated, tiny), processes only its local expert slice,
and a single psum over ``model`` combines expert contributions. No
all-to-all: token activations are replicated across the model axis (they
already are, post-attention), so EP costs one all-reduce of (T_loc, D) —
the same collective class as Megatron TP, and it overlaps with the next
layer's compute under the XLA latency-hiding scheduler.

Capacity-based dispatch keeps shapes static for jit: each expert takes at
most C = ceil(k * T_loc / E * capacity_factor) tokens per shard; overflow
drops (standard in EP training; the router aux loss keeps loads balanced).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.quantized import QuantizedTensor, dequantize
from repro.runtime import Runtime

from .layers import dense_apply, dense_init
from .mlp import ACTIVATIONS

__all__ = ["moe_init", "moe_apply", "expert_capacity"]


def moe_init(key, d_model: int, d_ff: int, n_experts: int, *,
             n_shared: int = 0, dtype=jnp.float32) -> dict:
    """Experts are stacked swiglu FFNs: gate/up (E, D, F), down (E, F, D)."""
    ks = jax.random.split(key, 5)
    s = d_model ** -0.5
    p = {
        "router": dense_init(ks[0], d_model, n_experts, dtype=jnp.float32),
        "gate": jax.random.normal(ks[1], (n_experts, d_model, d_ff), dtype) * s,
        "up": jax.random.normal(ks[2], (n_experts, d_model, d_ff), dtype) * s,
        "down": jax.random.normal(ks[3], (n_experts, d_ff, d_model), dtype)
                * (d_ff ** -0.5),
    }
    if n_shared:
        from .mlp import mlp_init
        p["shared"] = mlp_init(ks[4], d_model, d_ff * n_shared,
                               variant="swiglu", dtype=dtype)
    return p


def expert_capacity(n_tokens: int, n_experts: int, top_k: int,
                    capacity_factor: float = 1.25) -> int:
    c = int(n_tokens * top_k * capacity_factor / n_experts) + 1
    # round up to a lane-friendly multiple
    return max(8, -(-c // 8) * 8)


def _dense_w(w, dtype):
    return dequantize(w, dtype) if isinstance(w, QuantizedTensor) else w.astype(dtype)


def _moe_local(x, router_w, gate_w, up_w, down_w, *, top_k: int,
               n_experts_global: int, capacity_factor: float,
               model_axis: str | None):
    """Shard-local MoE body.
    x: (T_loc, D) — identical across the model axis.
    gate/up/down_w: this shard's expert slice (E_loc, D, F) / (E_loc, F, D).
    Returns (y (T_loc, D) partial [psum'ed if model_axis], aux losses dict).
    """
    t_loc, d = x.shape
    e_loc = gate_w.shape[0] if not isinstance(gate_w, QuantizedTensor) \
        else gate_w.logical_shape[0]
    shard = jax.lax.axis_index(model_axis) if model_axis else 0
    e0 = shard * e_loc

    logits = jnp.dot(x, router_w.astype(x.dtype),
                     preferred_element_type=jnp.float32)     # (T, E_glob)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)               # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize

    # ---- capacity dispatch for the local expert slice -------------------
    cap = expert_capacity(t_loc, n_experts_global, top_k, capacity_factor)
    flat_e = top_e.reshape(-1)                               # (T*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t_loc), top_k)
    local_e = flat_e - e0
    mine = (local_e >= 0) & (local_e < e_loc)
    local_e = jnp.where(mine, local_e, e_loc)                # park foreign in slot E_loc
    # position of each assignment within its expert queue
    onehot = jax.nn.one_hot(local_e, e_loc + 1, dtype=jnp.int32)  # (T*k, E+1)
    pos = jnp.cumsum(onehot, axis=0) * onehot                # running count
    slot = jnp.sum(pos, axis=-1) - 1                         # (T*k,)
    keep = mine & (slot < cap)
    e_idx = jnp.where(keep, local_e, e_loc)                  # drop -> parked row
    s_idx = jnp.where(keep, slot, 0)

    # gather tokens into (E_loc, C, D); parked row is scratch then discarded
    dispatch = jnp.zeros((e_loc + 1, cap), jnp.int32)
    dispatch = dispatch.at[e_idx, s_idx].set(flat_tok, mode="drop")
    valid = jnp.zeros((e_loc + 1, cap), jnp.bool_)
    valid = valid.at[e_idx, s_idx].set(keep, mode="drop")
    xg = jnp.take(x, dispatch[:e_loc].reshape(-1), axis=0)
    xg = xg.reshape(e_loc, cap, d)
    xg = jnp.where(valid[:e_loc][..., None], xg, 0)

    # ---- expert computation (swiglu) -------------------------------------
    gw = _dense_w(gate_w, xg.dtype)
    uw = _dense_w(up_w, xg.dtype)
    dw = _dense_w(down_w, xg.dtype)
    h = ACTIVATIONS["silu"](jnp.einsum("ecd,edf->ecf", xg, gw)) \
        * jnp.einsum("ecd,edf->ecf", xg, uw)
    y_e = jnp.einsum("ecf,efd->ecd", h, dw)                  # (E_loc, C, D)

    # ---- combine back (scatter-add weighted by gates) ---------------------
    w_pair = jnp.zeros((e_loc + 1, cap), jnp.float32)
    w_pair = w_pair.at[e_idx, s_idx].set(jnp.where(keep, flat_p, 0.0),
                                         mode="drop")
    # combine in the activation dtype: <= top_k additions per token, and the
    # (T_loc, D) f32 buffer + f32 psum would dominate the MoE layer's memory
    y = jnp.zeros((t_loc, d), x.dtype)
    y = y.at[dispatch[:e_loc].reshape(-1)].add(
        (y_e * w_pair[:e_loc][..., None].astype(y_e.dtype)).reshape(-1, d)
        .astype(x.dtype), mode="drop")

    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)

    # load-balance aux loss (Switch-style), computed on global stats
    me = jnp.mean(jax.nn.one_hot(top_e[:, 0], n_experts_global), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = n_experts_global * jnp.sum(me * ce)
    return y.astype(x.dtype), aux


def moe_apply(p: dict, x: jax.Array, *, top_k: int, n_experts: int,
              capacity_factor: float = 1.25, rt: Runtime):
    """x: (B, S, D) -> (B, S, D). Returns (y, aux_loss)."""
    b, s, d = x.shape
    router_w = p["router"]["w"]

    n_tok = b * s
    n_data = 1
    for a in (rt.data_axes or ()):
        n_data *= dict(rt.mesh.shape)[a] if rt.mesh is not None else 1
    if (rt.mesh is not None and rt.model_axis is not None
            and n_experts % rt.mesh.shape[rt.model_axis] == 0
            and n_tok % max(n_data, 1) == 0):
        axis = rt.model_axis
        dp = rt.data_axes if rt.data_axes else None
        fn = shard_map(
            functools.partial(_moe_local, top_k=top_k,
                              n_experts_global=n_experts,
                              capacity_factor=capacity_factor,
                              model_axis=axis),
            mesh=rt.mesh,
            in_specs=(P(dp, None), P(), P(axis, None, None),
                      P(axis, None, None), P(axis, None, None)),
            out_specs=(P(dp, None), P()),
            check_vma=False,
        )
        xf = x.reshape(b * s, d)
        y, aux = fn(xf, router_w, p["gate"], p["up"], p["down"])
        y = y.reshape(b, s, d)
    else:
        y, aux = _moe_local(x.reshape(b * s, d), router_w, p["gate"], p["up"],
                            p["down"], top_k=top_k,
                            n_experts_global=n_experts,
                            capacity_factor=capacity_factor, model_axis=None)
        y = y.reshape(b, s, d)

    if "shared" in p:
        from .mlp import mlp_apply
        y = y + mlp_apply(p["shared"], x, variant="swiglu", rt=rt)
    return y, aux
