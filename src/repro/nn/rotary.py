"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE splits the head_dim rotation frequencies into (temporal, height,
width) sections, each rotated by its own position stream. For the text-only
backbone (vision tower stubbed per the assignment) the three streams are
equal, which degenerates to RoPE exactly — implemented generally so real
(t, h, w) ids plug straight in.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rope_frequencies", "apply_rope", "apply_mrope"]


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jax.Array, positions: jax.Array, *,
               theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, dh); positions: (B, S) int32. Rotates pairs split at
    dh/2 (HF convention)."""
    dh = x.shape[-1]
    inv = rope_frequencies(dh, theta)                      # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]                      # (B, S, 1, dh/2)
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, *,
                sections: tuple[int, int, int], theta: float = 10000.0) -> jax.Array:
    """x: (B, S, H, dh); positions: (B, 3, S) for (t, h, w) streams;
    sections: frequency counts per stream summing to dh/2."""
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    inv = rope_frequencies(dh, theta)                      # (dh/2,)
    # pick the position stream per frequency section: (B, dh/2, S)
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=dh // 2)       # (dh/2,)
    pos = positions.astype(jnp.float32)[:, sec_id, :]
    # pos: (B, dh/2, S) -> angles (B, S, dh/2)
    ang = jnp.swapaxes(pos, 1, 2) * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)
