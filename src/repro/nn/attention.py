"""GQA attention: training/prefill (chunked online attention or Pallas flash
kernel) and decode (context-parallel flash-decode over a sequence-sharded KV
cache via shard_map).

Distribution notes
------------------
* Prefill/train: batch shards over data axes; the head dim of intermediates
  is constrained over the model axis (GSPMD pads uneven head counts — jit
  *inputs* are never unevenly sharded).
* Decode: the KV cache is a jit input, so its sharding must be even. KV head
  counts (1..16) generally aren't divisible by the 16-wide model axis, so the
  cache shards over the *sequence* dim instead, and attention runs as
  flash-decode context parallelism inside shard_map: each model-axis shard
  computes a local online-softmax partial + LSE stats; one tiny psum merges.
  The token's cache update lands in exactly one shard (clamped single-slot
  dynamic-update-slice — no collective, no full-cache copy).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import spx
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.runtime import Runtime, registry

from .layers import dense_apply, dense_init
from .rotary import apply_mrope, apply_rope

__all__ = ["attn_init", "attn_apply_dense", "attention_core",
           "decode_attention", "attn_decode_step", "paged_kv_write",
           "attn_paged_step", "quantize_kv", "dequantize_kv", "kv_lut"]

_NEG = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int,
              head_dim: int, *, qkv_bias: bool = False,
              dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, bias=qkv_bias,
                         dtype=dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, bias=qkv_bias,
                         dtype=dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, bias=qkv_bias,
                         dtype=dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype=dtype),
    }


def _project_qkv(p, x, n_heads, n_kv_heads, head_dim, rt):
    b, s, _ = x.shape
    q = dense_apply(p["wq"], x, rt).reshape(b, s, n_heads, head_dim)
    k = dense_apply(p["wk"], x, rt).reshape(b, s, n_kv_heads, head_dim)
    v = dense_apply(p["wv"], x, rt).reshape(b, s, n_kv_heads, head_dim)
    return q, k, v


def _apply_positional(q, k, positions, rope_theta, mrope_sections):
    if mrope_sections is not None:
        # positions: (B, 3, S)
        q = apply_mrope(q, positions, sections=mrope_sections,
                        theta=rope_theta)
        k = apply_mrope(k, positions, sections=mrope_sections,
                        theta=rope_theta)
    else:
        q = apply_rope(q, positions, theta=rope_theta)
        k = apply_rope(k, positions, theta=rope_theta)
    return q, k


# ---------------------------------------------------------------------------
# Core attention (train / prefill)
# ---------------------------------------------------------------------------

def _chunked_attention(q, k, v, *, causal: bool, q_chunk: int,
                       unroll: bool = False, q_offset=0):
    """Memory-bounded attention: scan over query chunks; each chunk attends
    to the full key range with absolute-position causal masking. Scores are
    (B, H, cq, Skv) per step — never (S, S) — and only the per-chunk scores
    are f32; K/V stay bf16 and 4-D so the head dim keeps its model-axis
    sharding (no batch*head merge, which would force all-gathers). Pure jnp
    (CPU / dry-run path); the TPU path is the Pallas flash kernel."""
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    cq = min(q_chunk, sq)
    if sq % cq:
        cq = sq  # ragged: single chunk (callers pass pow2 seqs)
    n_chunks = sq // cq
    scale = dh ** -0.5
    kv_pos = jnp.arange(skv)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk(carry, i):
        # checkpointed: the (B, H, cq, Skv) probs are recomputed in the
        # backward (flash-attention-style) instead of being stacked across
        # the chunk scan — that stack is quadratic in S.
        q_i = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=2)
        s = jax.lax.dot_general(
            q_i, k, (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32) * scale    # (B,H,cq,Skv)
        if causal:
            q_pos = q_offset + i * cq + jnp.arange(cq)
            s = jnp.where(q_pos[:, None] >= kv_pos[None, :], s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jax.lax.dot_general(
            p.astype(v.dtype), v, (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)            # (B,H,cq,dh)
        return carry, o.astype(q.dtype)

    if n_chunks == 1:
        _, o = chunk(None, 0)
        return o
    _, outs = jax.lax.scan(chunk, None, jnp.arange(n_chunks),
                           unroll=True if unroll else 1)
    # outs: (nc, B, H, cq, dh) -> (B, H, Sq, dh)
    outs = jnp.moveaxis(outs, 0, 2)
    return outs.reshape(b, h, sq, dh)


def attention_core(q, k, v, *, causal: bool, rt: Runtime):
    """q: (B, Hq, Sq, dh); k, v: (B, Hkv, Skv, dh) -> (B, Hq, Sq, dh)."""
    if getattr(rt, "attn_cp", False) and rt.mesh is not None \
            and q.shape[2] % dict(rt.mesh.shape)[rt.model_axis] == 0 \
            and q.shape[2] == k.shape[2]:
        return _attention_core_cp(q, k, v, causal=causal, rt=rt)
    impl = registry.resolve("flash_attention", rt.impl).impl
    if impl in ("pallas", "interpret"):
        return ops.flash_attention(q, k, v, causal=causal, impl=impl)
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)   # bf16, head dim stays sharded
        v = jnp.repeat(v, rep, axis=1)
    return _chunked_attention(q, k, v, causal=causal, q_chunk=rt.q_chunk,
                              unroll=rt.unroll)


def _attention_core_cp(q, k, v, *, causal: bool, rt: Runtime):
    """Context-parallel attention (long-prefill path, §Perf cell 2):
    queries stay sequence-sharded over the model axis; each shard gathers
    only the (small, GQA) K/V and computes its causal rows locally. Per
    layer this moves S*Hkv*dh*2 bytes instead of the 3+ full-activation
    (S x d_model) gathers the TP/SP path needs — the difference between
    collective-bound and compute-bound 32k prefill."""
    axis = rt.model_axis
    n = dict(rt.mesh.shape)[axis]
    b, hq, sq, dh = q.shape
    s_loc = sq // n
    dp = rt.data_axes if rt.data_axes else None

    def local(q_l, k_g, v_g):
        # q_l: (B, Hq, S/n, dh); k_g/v_g: (B, Hkv, S, dh) replicated
        off = jax.lax.axis_index(axis) * s_loc
        rep = hq // k_g.shape[1]
        if rep > 1:
            k_g = jnp.repeat(k_g, rep, axis=1)
            v_g = jnp.repeat(v_g, rep, axis=1)
        return _chunked_attention(q_l, k_g, v_g, causal=causal,
                                  q_chunk=min(rt.q_chunk, s_loc),
                                  unroll=rt.unroll, q_offset=off)

    fn = shard_map(
        local, mesh=rt.mesh,
        in_specs=(P(dp, None, axis, None), P(dp, None, None, None),
                  P(dp, None, None, None)),
        out_specs=P(dp, None, axis, None),
        check_vma=False)
    return fn(q, k, v)


def attn_apply_dense(p: dict, x: jax.Array, positions: jax.Array, *,
                     n_heads: int, n_kv_heads: int, head_dim: int,
                     causal: bool = True, rope_theta: float = 10000.0,
                     mrope_sections=None, rt: Runtime,
                     kv_out: bool = False,
                     cross_kv: tuple | None = None):
    """Full attention sublayer (projections + rope + core + output proj).

    cross_kv: optional (k, v) tuple — used by the enc-dec decoder's
    cross-attention (no rope on kv, not causal).
    Returns y or (y, (k, v)) if kv_out.
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, rt)
    if cross_kv is not None:
        k, v = cross_kv
        q = apply_rope(q, positions, theta=rope_theta) if mrope_sections is None else q
    elif positions is not None:
        q, k = _apply_positional(q, k, positions, rope_theta, mrope_sections)

    # sharding hints: TP mode shards heads over model (padded if uneven);
    # CP mode keeps q sequence-sharded (the KV gather happens in shard_map)
    if rt.mesh is not None and rt.model_axis is not None:
        from jax.sharding import NamedSharding
        dp = rt.data_axes if rt.data_axes else None
        if getattr(rt, "attn_cp", False):
            # CP: q/k/v all stay sequence-sharded through the projections
            # (compute stays 1/n per chip); the attention shard_map's
            # in_spec gathers only K/V at entry. Constraining k/v
            # "replicated" here instead makes GSPMD hoist the gather
            # before the projections — 16x replicated QKV/MLP compute
            # (measured: §Perf cell 2 iter 1).
            seq_spec = NamedSharding(rt.mesh, P(dp, rt.model_axis, None,
                                                None))
            q = jax.lax.with_sharding_constraint(q, seq_spec)
            k = jax.lax.with_sharding_constraint(k, seq_spec)
            v = jax.lax.with_sharding_constraint(v, seq_spec)
        else:
            q = jax.lax.with_sharding_constraint(
                q, NamedSharding(rt.mesh, P(dp, None, rt.model_axis, None)))
            k = jax.lax.with_sharding_constraint(
                k, NamedSharding(rt.mesh, P(dp, None, None, None)))
            v = jax.lax.with_sharding_constraint(
                v, NamedSharding(rt.mesh, P(dp, None, None, None)))

    qh = jnp.swapaxes(q, 1, 2)          # (B, Hq, S, dh)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    o = attention_core(qh, kh, vh, causal=causal and cross_kv is None, rt=rt)
    o = jnp.swapaxes(o, 1, 2).reshape(b, s, n_heads * head_dim)
    y = dense_apply(p["wo"], o, rt)
    if kv_out:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# Decode: context-parallel flash-decode over a seq-sharded KV cache
# ---------------------------------------------------------------------------

def kv_lut(scheme: str) -> jnp.ndarray:
    """f32 codebook LUT for a KV-cache scheme (pow2-padded; codes index it).
    Only 8-bit-code schemes are legal for the KV cache — the cache stores
    one uint8 code per element."""
    levels = spx.scheme_levels(scheme)
    if spx.code_width(levels) > 8:
        raise ValueError(f"KV scheme {scheme!r} needs >8-bit codes")
    return spx.codebook(levels, dtype=jnp.float32)


def quantize_kv(x, scheme: str = "uniform8", axis=-1):
    """Scheme-parameterized per-position quantization of K/V over a
    ``core/spx`` codebook. ``uniform8`` is the plain symmetric-int8
    baseline (255 uniform levels — NOT SPx); ``sp2_8`` / ``spx_8_x3`` are
    the paper's non-uniform level sets at the same 1-byte code width.
    x: (..., dh) -> (codes uint8, scale f32 (..., 1))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                    keepdims=True)
    scale = jnp.maximum(scale, 1e-8)
    codes = spx.quantize_to_codes(x, spx.scheme_levels(scheme), scale)
    return codes, scale


def dequantize_kv(codes, scale, scheme: str = "uniform8",
                  dtype=jnp.float32):
    """codes (uint8) + per-position scale -> values: lut[codes] * scale."""
    return spx.dequantize_codes(codes, kv_lut(scheme), scale, dtype=dtype)


def _local_flash_decode(q, k_cache, v_cache, k_new, v_new, pos, *,
                        shard_size: int, axis: str | None,
                        kv_scheme: str = "uniform8"):
    """Per-shard decode body. Shapes (local view):
      q: (B, Hq, dh); caches: (B, Hkv, S_loc, dh) arrays, OR dicts
      {"codes" uint8 (B,Hkv,S_loc,dh), "scale" f32 (B,Hkv,S_loc,1)} for the
      quantized cache — codebook codes under ``kv_scheme`` (uniform8 =
      plain int8 baseline; sp2_8/spx_8_x3 = non-uniform SPx). Quantization
      roughly halves the decode step's HBM-bound term vs bf16 —
      EXPERIMENTS.md §Perf cell 1; k_new/v_new: (B, Hkv, dh);
      pos: (B,) int32 — per-sequence global write/attend position
      (continuous batching: slots decode at different depths).
    Returns (out (B, Hq, dh), k_cache, v_cache) updated.
    """
    quantized = isinstance(k_cache, dict)
    b, hq, dh = q.shape
    hkv = (k_cache["codes"] if quantized else k_cache).shape[1]
    s_loc = (k_cache["codes"] if quantized else k_cache).shape[2]
    rep = hq // hkv

    shard_idx = jax.lax.axis_index(axis) if axis else 0
    local_start = shard_idx * shard_size
    local_pos = pos - local_start                    # (B,)
    in_range = (local_pos >= 0) & (local_pos < s_loc)
    idx = jnp.clip(local_pos, 0, s_loc - 1)

    # per-row single-slot masked write: read old slot, select, write back
    def upd(cache, new):
        def row(c_b, n_b, ix, ok):
            old = jax.lax.dynamic_slice_in_dim(c_b, ix, 1, axis=1)
            val = jnp.where(ok, n_b[:, None, :].astype(c_b.dtype), old)
            return jax.lax.dynamic_update_slice_in_dim(c_b, val, ix, axis=1)
        return jax.vmap(row)(cache, new, idx, in_range)

    if quantized:
        lut = kv_lut(kv_scheme)
        kc_new, ks_new = quantize_kv(k_new, kv_scheme)  # (B,Hkv,dh),(B,Hkv,1)
        vc_new, vs_new = quantize_kv(v_new, kv_scheme)
        k_cache = {"codes": upd(k_cache["codes"], kc_new),
                   "scale": upd(k_cache["scale"], ks_new)}
        v_cache = {"codes": upd(v_cache["codes"], vc_new),
                   "scale": upd(v_cache["scale"], vs_new)}
        # scores: q . (lut[codes] * scale) == (q . lut[codes]) * scale —
        # the per-position scale folds out of the dh contraction, so the
        # LUT gather is the only dequant work (scheme-independent)
        kr = jnp.repeat(k_cache["codes"], rep, axis=1)     # uint8
        ksc = jnp.repeat(k_cache["scale"], rep, axis=1)    # (B,Hq,S,1)
        kd = jnp.take(lut, kr.astype(jnp.int32), axis=0)   # f32 levels
        s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kd)
        s = s * ksc[..., 0] * (dh ** -0.5)
    else:
        k_cache = upd(k_cache, k_new)
        v_cache = upd(v_cache, v_new)
        kr = jnp.repeat(k_cache, rep, axis=1)   # (B, Hq, S_loc, dh)
        s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                       kr.astype(jnp.float32)) * (dh ** -0.5)

    gpos = local_start + jnp.arange(s_loc)
    s = jnp.where(gpos[None, None, :] <= pos[:, None, None], s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)                 # (B, Hq, 1)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    if quantized:
        vr = jnp.repeat(v_cache["codes"], rep, axis=1)
        vsc = jnp.repeat(v_cache["scale"], rep, axis=1)
        vd = jnp.take(lut, vr.astype(jnp.int32), axis=0)
        # fold the per-position V scale into p before the level einsum
        pv = p * vsc[..., 0]
        o = jnp.einsum("bhk,bhkd->bhd", pv, vd)
    else:
        vr = jnp.repeat(v_cache, rep, axis=1)
        o = jnp.einsum("bhk,bhkd->bhd", p, vr.astype(jnp.float32))

    if axis is not None:
        # LSE merge across shards (tiny collectives: (B,Hq,1) and (B,Hq,dh))
        m_g = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axis)
        o = jax.lax.psum(o * corr, axis)
        out = o / jnp.maximum(l_g, 1e-30)
    else:
        out = o / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype), k_cache, v_cache


def decode_attention(q, k_cache, v_cache, k_new, v_new, pos, *, rt: Runtime):
    """One-token attention against the cache, updating it.

    q: (B, Hq, dh); caches (B, Hkv, S, dh) [seq-sharded over rt.decode_seq_axis
    when a mesh is active]; k_new/v_new: (B, Hkv, dh); pos: () or (B,) int32
    (per-sequence positions for continuous batching).
    Returns (out, k_cache, v_cache).
    """
    quantized = isinstance(k_cache, dict)
    scheme = rt.kv_scheme
    s_total = (k_cache["codes"] if quantized else k_cache).shape[2]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (q.shape[0],))
    if rt.mesh is None or rt.decode_seq_axis is None:
        return _local_flash_decode(q, k_cache, v_cache, k_new, v_new, pos,
                                   shard_size=s_total, axis=None,
                                   kv_scheme=scheme)

    axis = rt.decode_seq_axis
    n_shards = rt.mesh.shape[axis]
    if s_total % n_shards or (rt.data_axes and
                              q.shape[0] % _n_axes(rt.mesh, rt.data_axes)):
        # non-divisible (tiny test shapes): local path, replicated
        return _local_flash_decode(q, k_cache, v_cache, k_new, v_new, pos,
                                   shard_size=s_total, axis=None,
                                   kv_scheme=scheme)
    shard_size = s_total // n_shards
    dp = rt.data_axes if rt.data_axes else None
    arr_spec = P(dp, None, axis, None)
    cache_spec = ({"codes": arr_spec, "scale": arr_spec} if quantized
                  else arr_spec)
    rep_spec = P(dp, None, None)

    fn = shard_map(
        functools.partial(_local_flash_decode, shard_size=shard_size,
                          axis=axis, kv_scheme=scheme),
        mesh=rt.mesh,
        in_specs=(rep_spec, cache_spec, cache_spec, rep_spec, rep_spec,
                  P(dp)),
        out_specs=(rep_spec, cache_spec, cache_spec),
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, k_new, v_new, pos)


def _n_axes(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= dict(mesh.shape)[a]
    return n


# ---------------------------------------------------------------------------
# Paged KV cache: chunked prefill + paged decode (serving — docs/SERVING.md)
# ---------------------------------------------------------------------------

def paged_kv_write(k_pages, v_pages, k_new, v_new, block_table, positions,
                   valid, kv_scheme: str = "uniform8"):
    """Scatter a chunk of new K/V rows into the physical page pools.

    k_pages/v_pages: (n_pages, Hkv, page_size, dh) arrays, OR dicts
    {"codes" uint8 (n_pages, Hkv, page_size, dh), "scale" f32
    (n_pages, Hkv, page_size, 1)} for the quantized pool (codes under
    ``kv_scheme``); k_new/v_new: (B, C, Hkv, dh); block_table:
    (B, max_pages) int32; positions: (B, C) absolute token positions;
    valid: (B, C) bool — False rows (chunk padding, inactive slots) are
    dropped via an out-of-range scatter index instead of a masked
    read-modify-write.
    """
    quantized = isinstance(k_pages, dict)
    n_pages, hkv, ps, dh = (k_pages["codes"] if quantized
                            else k_pages).shape
    logical = positions // ps                            # (B, C)
    phys = jnp.take_along_axis(block_table,
                               jnp.clip(logical, 0,
                                        block_table.shape[1] - 1), axis=1)
    phys = jnp.where(valid, phys, n_pages)               # OOB -> dropped
    off = positions % ps
    flat_p = phys.reshape(-1)
    flat_o = off.reshape(-1)

    def scatter(pages, new, width):
        flat = new.reshape(-1, hkv, width).astype(pages.dtype)
        return pages.at[flat_p, :, flat_o, :].set(flat, mode="drop")

    if quantized:
        kc, ks = quantize_kv(k_new, kv_scheme)    # (B,C,Hkv,dh), (B,C,Hkv,1)
        vc, vs = quantize_kv(v_new, kv_scheme)
        k_pages = {"codes": scatter(k_pages["codes"], kc, dh),
                   "scale": scatter(k_pages["scale"], ks, 1)}
        v_pages = {"codes": scatter(v_pages["codes"], vc, dh),
                   "scale": scatter(v_pages["scale"], vs, 1)}
        return k_pages, v_pages
    return scatter(k_pages, k_new, dh), scatter(v_pages, v_new, dh)


def _gather_pages(pages, block_table, kv_scheme: str):
    """Gather one sequence's pages into a contiguous (B, Hkv, S, dh) view;
    dict (quantized) pools are dequantized after the gather, so the f32
    values are materialized *context-sized* (S = max_pages x page_size)
    per chunk call — only the HBM-resident pool stays 1 byte/element.
    That's the prefill path's trade (compute-bound, gather amortized);
    the decode hot path never does this, it streams codes through the
    fused-dequant kernel instead."""
    bt = block_table
    if isinstance(pages, dict):
        b = bt.shape[0]
        hkv, ps, dh = pages["codes"].shape[1:]
        s_max = bt.shape[1] * ps
        codes = jnp.moveaxis(pages["codes"][bt], 2, 1) \
            .reshape(b, hkv, s_max, dh)
        scale = jnp.moveaxis(pages["scale"][bt], 2, 1) \
            .reshape(b, hkv, s_max, 1)
        return dequantize_kv(codes, scale, kv_scheme, dtype=jnp.float32)
    b = bt.shape[0]
    hkv, ps, dh = pages.shape[1:]
    return jnp.moveaxis(pages[bt], 2, 1).reshape(b, hkv, bt.shape[1] * ps,
                                                 dh)


def _paged_chunk_attention(q, k_pages, v_pages, block_table, positions,
                           attend_len, kv_scheme: str = "uniform8"):
    """Attention of a C-token chunk against the full paged context
    (including the chunk itself, already written to the pages).

    q: (B, Hq, C, dh); positions: (B, C) absolute query positions;
    attend_len: (B,) total attendable tokens. Gathers this sequence's
    pages into a contiguous view — prefill is compute-bound, so the
    gather's bytes are amortized; the single-token hot path goes through
    the paged-attention kernel instead. Returns (B, Hq, C, dh).
    """
    b, hq, c, dh = q.shape
    quantized = isinstance(k_pages, dict)
    hkv, ps = (k_pages["codes"] if quantized else k_pages).shape[1:3]
    s_max = block_table.shape[1] * ps
    rep = hq // hkv
    k = _gather_pages(k_pages, block_table, kv_scheme)
    v = _gather_pages(v_pages, block_table, kv_scheme)
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    kv_pos = jnp.arange(s_max)
    mask = ((kv_pos[None, None, :] <= positions[:, :, None])
            & (kv_pos[None, None, :] < attend_len[:, None, None]))
    s = jnp.where(mask[:, None], s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return (o / jnp.maximum(l, 1e-30)).astype(q.dtype)


def attn_paged_step(p: dict, x: jax.Array, ctx_len: jax.Array,
                    block_table: jax.Array, cache: dict, *, n_heads: int,
                    n_kv_heads: int, head_dim: int, n_valid: jax.Array,
                    rope_theta: float = 10000.0, mrope_sections=None,
                    rt: Runtime, fused: bool = False):
    """Attention sublayer over the paged KV cache — one code path for both
    chunked prefill (C > 1) and decode (C == 1, dispatched to the
    paged-attention kernel via the registry).

    x: (B, C, D) — the next C tokens of each sequence; ctx_len: (B,) int32
    tokens already in the pages; n_valid: (B,) int32 valid tokens in this
    chunk (< C for ragged tails / inactive rows — invalid tokens are
    neither written nor trusted); cache: {"kp", "vp"} physical pools —
    arrays, or {"codes", "scale"} dicts for the quantized pool
    (``rt.kv_scheme`` picks the level set; decode then dispatches to the
    fused-dequant paged-attention kernel). ``fused`` routes the attention
    through the ragged decode megakernel (``ops.paged_decode_ragged``) —
    one launch for the whole ragged window, n_valid as the per-slot
    ``q_len``, dense or quantized pools alike; the serving engine's
    decode/verify tick sets it, chunked prefill keeps the gather path.
    Returns (y (B, C, D), new_cache).
    """
    b, c, _ = x.shape
    quantized = isinstance(cache["kp"], dict)
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, rt)
    positions = ctx_len[:, None] + jnp.arange(c, dtype=jnp.int32)   # (B, C)
    if mrope_sections is not None:
        # text-stream M-RoPE paged positions: the three rotary streams
        # share the token index (equivalent to plain RoPE for text-only
        # decode — exactly what the dense path broadcasts). RoPE happens
        # before the cache write, so the fused/quantized paths need no
        # position plumbing of their own.
        positions3 = jnp.broadcast_to(positions[:, None, :], (b, 3, c))
        q, k = _apply_positional(q, k, positions3, rope_theta,
                                 mrope_sections)
    else:
        q, k = _apply_positional(q, k, positions, rope_theta, None)
    valid = jnp.arange(c)[None, :] < n_valid[:, None]               # (B, C)
    kp, vp = paged_kv_write(cache["kp"], cache["vp"], k, v, block_table,
                            positions, valid, kv_scheme=rt.kv_scheme)
    attend_len = ctx_len + n_valid
    if fused:
        # one megakernel launch for the whole (slot, attend_len) ragged
        # window — window row i of slot b attends cache positions
        # <= ctx_len[b] + i, rows past n_valid[b] come back zero (unused)
        out = ops.paged_decode_ragged(
            q, kp, vp, block_table, ctx_len, n_valid,
            kv_scheme=rt.kv_scheme if quantized else None, impl=rt.impl)
        o = out.reshape(b, c, n_heads * head_dim)
    elif c == 1:
        q1 = q[:, 0].reshape(b, n_heads, head_dim)
        if quantized:
            out = ops.paged_attention_quant(q1, kp, vp, block_table,
                                            attend_len,
                                            kv_scheme=rt.kv_scheme,
                                            impl=rt.impl)
        else:
            out = ops.paged_attention(q1, kp, vp, block_table, attend_len,
                                      impl=rt.impl)
        o = out[:, None]                                 # (B, 1, Hq*dh)->..
        o = o.reshape(b, 1, n_heads * head_dim)
    else:
        qh = jnp.swapaxes(q, 1, 2)                       # (B, Hq, C, dh)
        o = _paged_chunk_attention(qh, kp, vp, block_table, positions,
                                   attend_len, kv_scheme=rt.kv_scheme)
        o = jnp.swapaxes(o, 1, 2).reshape(b, c, n_heads * head_dim)
    y = dense_apply(p["wo"], o, rt)
    return y, dict(cache, kp=kp, vp=vp)


def attn_decode_step(p: dict, x: jax.Array, pos: jax.Array, kv_cache: tuple, *,
                     n_heads: int, n_kv_heads: int, head_dim: int,
                     rope_theta: float = 10000.0, mrope_sections=None,
                     rt: Runtime, cross_kv: tuple | None = None):
    """One-token attention sublayer. x: (B, 1, D); kv_cache: (k, v) each
    (B, Hkv, S, dh). Returns (y (B,1,D), new_cache)."""
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, rt)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    if mrope_sections is not None:
        positions3 = jnp.broadcast_to(pos_b[:, None, None], (b, 3, 1))
        q, k = _apply_positional(q, k, positions3, rope_theta, mrope_sections)
    else:
        q, k = _apply_positional(q, k, pos_b[:, None], rope_theta,
                                 mrope_sections)

    if cross_kv is not None:
        # cross-attention: static KV (encoder output projections), no cache
        kh = jnp.swapaxes(cross_kv[0], 1, 2)
        vh = jnp.swapaxes(cross_kv[1], 1, 2)
        qh = jnp.swapaxes(q, 1, 2)
        o = attention_core(qh, kh, vh, causal=False, rt=rt)
        y = jnp.swapaxes(o, 1, 2).reshape(b, 1, n_heads * head_dim)
        return dense_apply(p["wo"], y, rt), kv_cache

    k_cache, v_cache = kv_cache
    out, k_cache, v_cache = decode_attention(
        q[:, 0].reshape(b, n_heads, head_dim),
        k_cache, v_cache,
        k[:, 0].reshape(b, n_kv_heads, head_dim),
        v[:, 0].reshape(b, n_kv_heads, head_dim),
        pos, rt=rt)
    y = dense_apply(p["wo"], out.reshape(b, 1, n_heads * head_dim), rt)
    return y, (k_cache, v_cache)
