"""Block assembly and the scan-over-periods stack.

An architecture is a repeating *pattern* of slots (ArchConfig.pattern), e.g.
  dense transformer : ("attn+dense",)
  MoE transformer   : ("attn+moe",)
  Jamba period      : ("attn+moe", "mamba+dense", "mamba+moe", ... ) x8
  xLSTM period      : ("mlstm", "mlstm", "mlstm", "slstm+dense")
Parameters for each slot are stacked over periods (leading P dim) and the
stack scans over periods — HLO stays O(pattern), not O(n_layers), which keeps
the 512-device dry-run compile tractable for 61-layer/1T-param configs.

Decode carries per-slot state (KV caches / SSM states), also stacked over
periods and threaded through the same scan.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.runtime import Runtime

from . import ssm
from .attention import (_apply_positional, _project_qkv, attention_core,
                        attn_apply_dense, attn_decode_step, attn_init,
                        attn_paged_step)
from .layers import norm_apply, norm_init, opt_barrier
from .mlp import mlp_apply, mlp_init
from .moe import moe_apply, moe_init

__all__ = ["stack_init", "stack_apply", "stack_prefill", "stack_decode",
           "stack_paged", "slot_init_cache", "slot_init_paged_cache",
           "SLOT_KINDS"]

SLOT_KINDS = ("attn", "xdec", "mamba", "mlstm", "slstm")


def _parse_slot(slot: str):
    parts = slot.split("+")
    mixer = parts[0]
    ffn = parts[1] if len(parts) > 1 else None
    assert mixer in SLOT_KINDS, slot
    return mixer, ffn


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _slot_init(key, slot: str, cfg: ArchConfig, dtype) -> dict:
    mixer, ffn = _parse_slot(slot)
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": norm_init(cfg.norm, d, dtype)}
    if mixer in ("attn", "xdec"):
        p["attn"] = attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.dh,
                              qkv_bias=cfg.qkv_bias, dtype=dtype)
        if mixer == "xdec":
            p["xattn"] = attn_init(ks[3], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.dh, qkv_bias=cfg.qkv_bias, dtype=dtype)
            p["norm_x"] = norm_init(cfg.norm, d, dtype)
    elif mixer == "mamba":
        p["mamba"] = ssm.mamba_init(
            ks[0], d, d_state=cfg.ssm_d_state, d_conv=cfg.ssm_d_conv,
            expand=cfg.ssm_expand, dt_rank=cfg.ssm_dt_rank or None,
            dtype=dtype)
    elif mixer == "mlstm":
        p["mlstm"] = ssm.mlstm_init(ks[0], d, n_heads=cfg.lstm_heads,
                                    expand=cfg.ssm_expand,
                                    d_conv=cfg.ssm_d_conv, dtype=dtype)
    elif mixer == "slstm":
        p["slstm"] = ssm.slstm_init(ks[0], d, n_heads=cfg.lstm_heads,
                                    dtype=dtype)
    if ffn == "dense":
        p["norm2"] = norm_init(cfg.norm, d, dtype)
        # d_ff=0 (xLSTM assignment): blocks carry their own projections; the
        # sLSTM slot still gets a 4/3-expansion FFN per the xLSTM paper
        d_ff = cfg.d_ff or ((4 * d // 3 + 127) // 128 * 128)
        p["mlp"] = mlp_init(ks[1], d, d_ff, variant=cfg.mlp_variant,
                            act=cfg.act, dtype=dtype)
    elif ffn == "moe":
        p["norm2"] = norm_init(cfg.norm, d, dtype)
        p["moe"] = moe_init(ks[1], d, cfg.d_ff, cfg.n_experts,
                            n_shared=cfg.n_shared_experts, dtype=dtype)
    return p


def stack_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    """Stacked params: {'slots': [slot_pytree(P, ...), ...]}."""
    n_p = cfg.n_periods
    slots = []
    for j, slot in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(key, j), n_p)
        per_period = [_slot_init(k, slot, cfg, dtype) for k in keys]
        slots.append(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per_period))
    return {"slots": slots}


# ---------------------------------------------------------------------------
# Apply — train / prefill / decode share one slot dispatcher
# ---------------------------------------------------------------------------

def _cross_kv(p_attn: dict, enc_out: jax.Array, n_kv_heads: int,
              head_dim: int, rt: Runtime):
    """Per-layer cross-attention K/V projections of the encoder output."""
    from .layers import dense_apply
    b, s, _ = enc_out.shape
    k = dense_apply(p_attn["wk"], enc_out, rt).reshape(b, s, n_kv_heads,
                                                       head_dim)
    v = dense_apply(p_attn["wv"], enc_out, rt).reshape(b, s, n_kv_heads,
                                                       head_dim)
    return k, v


def _slab_step(cache: dict, state_idx, n_valid, step_fn):
    """Run an SSM paged step against the slab region: gather each row's
    slab (``state_idx[:, 0]``), step, scatter the new state back. Rows
    with ``n_valid == 0`` (inactive slots in a mixed prefill/decode tick)
    and rows whose slab index is the out-of-range sentinel are dropped by
    the scatter — their slabs stay bit-identical (the step itself is also
    identity-masked, so this is belt and braces). ``cache``: per-slot
    state leaves shaped (n_slabs, ...)."""
    slab_idx = state_idx[:, 0].astype(jnp.int32)
    n_slabs = next(iter(cache.values())).shape[0]
    safe = jnp.clip(slab_idx, 0, max(n_slabs - 1, 0))
    state_b = {k: leaf[safe] for k, leaf in cache.items()}
    y, ns = step_fn(state_b)
    dst = jnp.where(n_valid > 0, slab_idx, n_slabs)
    new_cache = {k: leaf.at[dst].set(ns[k].astype(leaf.dtype), mode="drop")
                 for k, leaf in cache.items()}
    return y, new_cache


def _slot_apply(slot: str, p: dict, x, positions, cfg: ArchConfig,
                rt: Runtime, *, mode: str, cache=None, pos=None,
                enc_out=None, causal: bool = True, paged_ctx=None,
                fused: bool = False):
    """mode: 'train' | 'prefill' | 'decode' | 'paged'. Returns
    (x, new_cache, aux). Paged mode (serving: chunked prefill + paged
    decode through one path) takes ``paged_ctx = (ctx_len, block_table,
    n_valid, state_idx)`` and routes per *slot kind*: attention and
    decoder self-attention write token pages, SSM mixers read/write their
    row of the slab region (``state_idx[:, 0]``), cross-attention reads
    the shared read-only cross region (``state_idx[:, 1]``) — one
    state-cache, heterogeneous layers."""
    mixer, ffn = _parse_slot(slot)
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    h = norm_apply(cfg.norm, p["norm1"], x)
    if mode == "paged":
        ctx_len, block_table, n_valid, state_idx = paged_ctx
    if mixer in ("attn", "xdec"):
        if mode == "paged":
            y, new_cache = attn_paged_step(
                p["attn"], h, ctx_len, block_table, cache,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.dh, n_valid=n_valid,
                rope_theta=cfg.rope_theta,
                mrope_sections=cfg.mrope_sections, rt=rt, fused=fused)
        elif mode == "decode":
            y, kv = attn_decode_step(
                p["attn"], h, pos, (cache["k"], cache["v"]),
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.dh, rope_theta=cfg.rope_theta,
                mrope_sections=cfg.mrope_sections, rt=rt)
            new_cache = dict(cache, k=kv[0], v=kv[1])
        elif mode == "prefill":
            y, (k, v) = attn_apply_dense(
                p["attn"], h, positions, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.dh, causal=causal,
                rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
                rt=rt, kv_out=True)
            # write prefix into the (possibly longer) cache: (B,S,Hkv,dh) ->
            # (B,Hkv,S,dh) layout
            kT, vT = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
            new_cache = dict(cache)

            def write(slot_cache, val):
                if isinstance(slot_cache, dict):   # quantized KV (rt.kv_scheme)
                    from .attention import quantize_kv
                    codes, scale = quantize_kv(val, rt.kv_scheme)
                    return {"codes": jax.lax.dynamic_update_slice_in_dim(
                                slot_cache["codes"], codes, 0, axis=2),
                            "scale": jax.lax.dynamic_update_slice_in_dim(
                                slot_cache["scale"], scale, 0, axis=2)}
                return jax.lax.dynamic_update_slice_in_dim(
                    slot_cache, val.astype(slot_cache.dtype), 0, axis=2)

            new_cache["k"] = write(cache["k"], kT)
            new_cache["v"] = write(cache["v"], vT)
        else:
            y = attn_apply_dense(
                p["attn"], h, positions, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.dh, causal=causal,
                rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
                rt=rt)
        x = x + y
        if mixer == "xdec":
            hx = norm_apply(cfg.norm, p["norm_x"], x)
            if mode == "paged":
                # cross-attention against the shared read-only cross
                # region: each row reads the encoder-output K/V entry its
                # sequence was mapped to at admission (state_idx[:, 1]);
                # entries are written once by the engine's encoder pass
                # and never mutated here. Matches the dense path: q is
                # roped at the absolute token position, K/V are unroped,
                # attention is non-causal over all encoder frames.
                bq, cq, _ = hx.shape
                n_cross = new_cache["xk"].shape[0]
                xs_idx = jnp.clip(state_idx[:, 1], 0,
                                  max(n_cross - 1, 0)).astype(jnp.int32)
                kh = new_cache["xk"][xs_idx]       # (B, Hkv, S_enc, dh)
                vh = new_cache["xv"][xs_idx]
                qx, kx, _ = _project_qkv(p["xattn"], hx, cfg.n_heads,
                                         cfg.n_kv_heads, cfg.dh, rt)
                positions_x = ctx_len[:, None] \
                    + jnp.arange(cq, dtype=jnp.int32)
                qx, _ = _apply_positional(qx, kx, positions_x,
                                          cfg.rope_theta, None)
                o = attention_core(jnp.swapaxes(qx, 1, 2), kh, vh,
                                   causal=False, rt=rt)
                y = jnp.swapaxes(o, 1, 2).reshape(bq, cq,
                                                  cfg.n_heads * cfg.dh)
                from .layers import dense_apply
                y = dense_apply(p["xattn"]["wo"], y, rt)
            elif mode == "decode":
                xkv = (cache["xk"], cache["xv"])
                y, _ = attn_decode_step(
                    p["xattn"], hx, pos, None, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads, head_dim=cfg.dh,
                    rope_theta=cfg.rope_theta, rt=rt,
                    cross_kv=(jnp.swapaxes(xkv[0], 1, 2),
                              jnp.swapaxes(xkv[1], 1, 2)))
            else:
                xk, xv = _cross_kv(p["xattn"], enc_out, cfg.n_kv_heads,
                                   cfg.dh, rt)
                y = attn_apply_dense(
                    p["xattn"], hx, positions, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads, head_dim=cfg.dh, causal=False,
                    rope_theta=cfg.rope_theta, rt=rt, cross_kv=(xk, xv))
                if mode == "prefill":
                    new_cache = dict(new_cache,
                                     xk=jnp.swapaxes(xk, 1, 2)
                                     .astype(cache["xk"].dtype),
                                     xv=jnp.swapaxes(xv, 1, 2)
                                     .astype(cache["xv"].dtype))
            x = x + y
    elif mixer == "mamba":
        if mode == "paged":
            y, new_cache = _slab_step(
                cache, state_idx, n_valid,
                lambda st: ssm.mamba_paged_step(p["mamba"], h, st, n_valid,
                                                rt=rt))
        elif mode == "decode":
            y, new_cache = ssm.mamba_decode_step(p["mamba"], h, cache, rt=rt)
        elif mode == "prefill":
            y, new_cache = ssm.mamba_apply(p["mamba"], h, rt=rt,
                                           return_state=True)
        else:
            y = ssm.mamba_apply(p["mamba"], h, rt=rt)
        x = x + y
    elif mixer == "mlstm":
        if mode == "paged":
            y, new_cache = _slab_step(
                cache, state_idx, n_valid,
                lambda st: ssm.mlstm_paged_step(p["mlstm"], h, st, n_valid,
                                                rt=rt,
                                                n_heads=cfg.lstm_heads))
        elif mode == "decode":
            y, new_cache = ssm.mlstm_decode_step(p["mlstm"], h, cache, rt=rt,
                                                 n_heads=cfg.lstm_heads)
        elif mode == "prefill":
            y, new_cache = ssm.mlstm_apply(p["mlstm"], h, rt=rt,
                                           n_heads=cfg.lstm_heads,
                                           return_state=True)
        else:
            y = ssm.mlstm_apply(p["mlstm"], h, rt=rt, n_heads=cfg.lstm_heads)
        x = x + y
    elif mixer == "slstm":
        if mode == "paged":
            y, new_cache = _slab_step(
                cache, state_idx, n_valid,
                lambda st: ssm.slstm_paged_step(p["slstm"], h, st, n_valid,
                                                rt=rt))
        elif mode == "decode":
            y, new_cache = ssm.slstm_decode_step(p["slstm"], h, cache, rt=rt)
        elif mode == "prefill":
            y, new_cache = ssm.slstm_apply(p["slstm"], h, rt=rt,
                                           return_state=True)
        else:
            y = ssm.slstm_apply(p["slstm"], h, rt=rt)
        x = x + y

    if ffn == "dense":
        h = norm_apply(cfg.norm, p["norm2"], x)
        x = x + mlp_apply(p["mlp"], h, variant=cfg.mlp_variant, act=cfg.act,
                          rt=rt)
    elif ffn == "moe":
        h = norm_apply(cfg.norm, p["norm2"], x)
        y, a = moe_apply(p["moe"], h, top_k=cfg.top_k,
                         n_experts=cfg.n_experts,
                         capacity_factor=cfg.capacity_factor, rt=rt)
        x = x + y
        aux = aux + a
    return x, new_cache, aux


def _sp_constrain(x, rt: Runtime):
    """Sequence-parallel residual stream: between layers the (B, S, D) carry
    shards over the model axis on S. This is what keeps the remat'd carry
    stack (L x B x S x D) inside HBM at production batch sizes; GSPMD turns
    the layer-boundary transitions into reduce-scatter/all-gather pairs (the
    Megatron-SP pattern — same bytes as the TP all-reduce they replace)."""
    if rt.mesh is None or x.ndim != 3 or rt.model_axis is None:
        return x
    n_model = dict(rt.mesh.shape).get(rt.model_axis, 1)
    if x.shape[1] % n_model:
        return x
    from jax.sharding import NamedSharding
    dp = rt.data_axes if rt.data_axes else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rt.mesh, jax.sharding.PartitionSpec(
            dp, rt.model_axis, None)))


def _period_body(carry, xs, *, cfg: ArchConfig, rt: Runtime, mode: str,
                 positions=None, enc_out=None, causal: bool = True,
                 paged_ctx=None, fused: bool = False):
    if mode == "decode":
        x, pos, aux = carry
        slot_params, caches = xs
    elif mode in ("prefill", "paged"):
        x, aux = carry
        slot_params, caches = xs
        pos = None
    else:
        x, aux = carry
        slot_params, caches = xs, [None] * len(cfg.pattern)
        pos = None
        # keep the remat'd carry stack in the carry's own (bf16) dtype: the
        # barrier stops XLA fusing the first norm's f32 convert into the
        # residual-stack write (which would double its bytes)
        x = opt_barrier(x)
    new_caches = []
    for j, slot in enumerate(cfg.pattern):
        def run_slot(sp, xx, _slot=slot, _cache=caches[j]):
            if mode == "train":
                # keep the checkpoint-saved slot input in its own dtype
                # (block f32-convert fusion into the residual save)
                xx = opt_barrier(xx)
            return _slot_apply(_slot, sp, xx, positions, cfg, rt, mode=mode,
                               cache=_cache, pos=pos, enc_out=enc_out,
                               causal=causal, paged_ctx=paged_ctx,
                               fused=fused)
        if mode == "train" and rt.remat != "none" and len(cfg.pattern) > 1:
            # hierarchical remat: the period body is already checkpointed;
            # checkpointing each slot too keeps the backward's recompute
            # liveset to ONE slot (8 Jamba slots at d=8192 would otherwise
            # be live together during the period recompute)
            run_slot = jax.checkpoint(run_slot, prevent_cse=False)
        x, nc, a = run_slot(slot_params[j], x)
        new_caches.append(nc)
        aux = aux + a
    if mode != "decode":
        x = _sp_constrain(x, rt)
    if mode == "decode":
        return (x, pos, aux), new_caches
    if mode in ("prefill", "paged"):
        return (x, aux), new_caches
    return (x, aux), None


def stack_apply(params: dict, x: jax.Array, positions, cfg: ArchConfig,
                rt: Runtime, enc_out=None, causal: bool = True,
                pattern: tuple | None = None):
    """Train-mode stack. Returns (x, aux_loss_sum)."""
    cfg_eff = cfg if pattern is None else _with_pattern(cfg, pattern)
    body = functools.partial(_period_body, cfg=cfg_eff, rt=rt, mode="train",
                             positions=positions, enc_out=enc_out,
                             causal=causal)
    if rt.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if rt.remat == "dots" else None)
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               tuple(params["slots"]),
                               unroll=True if rt.unroll else 1)
    return x, aux


def stack_prefill(params: dict, x: jax.Array, positions, cfg: ArchConfig,
                  rt: Runtime, caches, enc_out=None):
    """Prefill: like train but returns per-slot caches stacked over periods.
    ``caches`` are pre-allocated (full decode length) and the prefix is
    written in-place."""
    def body(carry, xs):
        return _period_body(carry, xs, cfg=cfg, rt=rt, mode="prefill",
                            positions=positions, enc_out=enc_out)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (tuple(params["slots"]), tuple(caches)),
        unroll=True if rt.unroll else 1)
    return x, new_caches, aux


def stack_decode(params: dict, x: jax.Array, pos, cfg: ArchConfig,
                 rt: Runtime, caches):
    """One-token decode through all periods, threading caches."""
    def body(carry, xs):
        return _period_body(carry, xs, cfg=cfg, rt=rt, mode="decode")
    (x, _, aux), new_caches = jax.lax.scan(
        body, (x, pos, jnp.zeros((), jnp.float32)),
        (tuple(params["slots"]), tuple(caches)),
        unroll=True if rt.unroll else 1)
    return x, new_caches


def stack_paged(params: dict, x: jax.Array, ctx_len, block_table, n_valid,
                state_idx, cfg: ArchConfig, rt: Runtime, caches, *,
                fused: bool = False):
    """C-token step over the unified state-cache — chunked prefill (C > 1)
    and paged decode (C == 1) share this path, for every slot kind. x:
    (B, C, D); ctx_len/n_valid: (B,) int32; block_table: (B, max_pages)
    int32; state_idx: (B, 2) int32 — column 0 is each row's slab index
    (SSM state), column 1 its cross-region entry (encoder-output KV);
    out-of-range sentinels mark rows without that region. caches:
    per-slot region pytrees stacked over periods
    (``slot_init_paged_cache``). ``fused`` routes every attention through
    the ragged decode megakernel (serving decode/verify ticks; prefill
    chunks stay on the gather path). Returns (x, new_caches)."""
    def body(carry, xs):
        return _period_body(carry, xs, cfg=cfg, rt=rt, mode="paged",
                            paged_ctx=(ctx_len, block_table, n_valid,
                                       state_idx),
                            fused=fused)
    (x, _), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (tuple(params["slots"]), tuple(caches)),
        unroll=True if rt.unroll else 1)
    return x, new_caches


def _with_pattern(cfg: ArchConfig, pattern: tuple) -> ArchConfig:
    import dataclasses
    n_layers = cfg.n_enc_layers if cfg.enc_dec else cfg.n_layers
    return dataclasses.replace(cfg, pattern=pattern, n_layers=n_layers)


# ---------------------------------------------------------------------------
# Cache allocation
# ---------------------------------------------------------------------------

def slot_init_cache(slot: str, cfg: ArchConfig, batch: int, max_seq: int,
                    dtype=jnp.bfloat16, n_periods: int | None = None,
                    kv_quant: bool = False):
    """Zero cache for one slot, stacked over periods (leading P dim).
    kv_quant: store attention K/V as codebook codes (uint8) + per-position
    scale. The level set is NOT fixed here — codes are interpreted under
    ``Runtime.kv_scheme`` at quantize/attend time (``uniform8`` = the plain
    int8 baseline, ``sp2_8``/``spx_8_x3`` = non-uniform SPx), so the cache
    layout is scheme-independent: 1 byte/element + 4 bytes/position."""
    mixer, _ = _parse_slot(slot)
    P = n_periods if n_periods is not None else cfg.n_periods

    def stackP(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (P,) + a.shape).copy(), tree)

    if mixer in ("attn", "xdec"):
        if kv_quant:
            def qkv():
                return {"codes": jnp.zeros((P, batch, cfg.n_kv_heads,
                                            max_seq, cfg.dh), jnp.uint8),
                        "scale": jnp.ones((P, batch, cfg.n_kv_heads,
                                           max_seq, 1), jnp.float32)}
            cache = {"k": qkv(), "v": qkv()}
        else:
            kv = jnp.zeros((P, batch, cfg.n_kv_heads, max_seq, cfg.dh),
                           dtype)
            cache = {"k": kv, "v": kv + 0}
        if mixer == "xdec":
            xkv = jnp.zeros((P, batch, cfg.n_kv_heads, cfg.enc_seq_len,
                             cfg.dh), dtype)
            cache["xk"] = xkv
            cache["xv"] = xkv + 0
        return cache
    if mixer == "mamba":
        di = cfg.ssm_expand * cfg.d_model
        base = {"h": jnp.zeros((batch, di, cfg.ssm_d_state), jnp.float32),
                "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, di), dtype)}
        return stackP(base)
    if mixer == "mlstm":
        di = cfg.ssm_expand * cfg.d_model
        nh = cfg.lstm_heads
        dh = di // nh
        base = {"C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, nh, dh), jnp.float32),
                "m": jnp.zeros((batch, nh), jnp.float32),
                "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, di), dtype)}
        return stackP(base)
    if mixer == "slstm":
        nh = cfg.lstm_heads
        dh = cfg.d_model // nh
        base = {k: jnp.zeros((batch, nh, dh), jnp.float32)
                for k in ("c", "n", "m", "h")}
        return stackP(base)
    raise ValueError(slot)


def slot_init_paged_cache(slot: str, cfg: ArchConfig, n_pages: int,
                          page_size: int, dtype=jnp.bfloat16,
                          n_periods: int | None = None,
                          kv_quant: bool = False, n_slabs: int = 0,
                          n_cross: int = 0):
    """Device arrays for one slot's state-cache region, stacked over
    periods (axis 0) with the shared pool axis at axis 1:

      * attn: token-paged K/V pools {"kp", "vp"} each
        (P, n_pages, Hkv, page_size, dh) — or, when ``kv_quant``, each a
        {"codes" uint8, "scale" f32 (..., 1)} dict (codes interpreted
        under ``Runtime.kv_scheme``; ``dtype`` is ignored for them)
      * xdec: the same self-attention pools plus the read-only cross
        region {"xk", "xv"} each (P, n_cross, Hkv, enc_seq_len, dh) —
        one entry per *distinct input*, shared across sequences
      * mamba / mlstm / slstm: the slab region — per-sequence recurrent
        state leaves shaped (P, n_slabs, ...); scan/cell states are f32,
        conv windows use ``dtype``

    Every region is shared by every sequence — ownership lives in the
    host-side StateCache (serving/kv_cache.py); the device only ever sees
    block tables and (slab, cross) index columns."""
    mixer, _ = _parse_slot(slot)
    P = n_periods if n_periods is not None else cfg.n_periods
    if mixer in ("attn", "xdec"):
        if kv_quant:
            def pool():
                return {"codes": jnp.zeros((P, n_pages, cfg.n_kv_heads,
                                            page_size, cfg.dh), jnp.uint8),
                        "scale": jnp.ones((P, n_pages, cfg.n_kv_heads,
                                           page_size, 1), jnp.float32)}
            cache = {"kp": pool(), "vp": pool()}
        else:
            kp = jnp.zeros((P, n_pages, cfg.n_kv_heads, page_size, cfg.dh),
                           dtype)
            cache = {"kp": kp, "vp": kp + 0}
        if mixer == "xdec":
            xkv = jnp.zeros((P, n_cross, cfg.n_kv_heads, cfg.enc_seq_len,
                             cfg.dh), dtype)
            cache["xk"] = xkv
            cache["xv"] = xkv + 0
        return cache
    if mixer == "mamba":
        di = cfg.ssm_expand * cfg.d_model
        return {"h": jnp.zeros((P, n_slabs, di, cfg.ssm_d_state),
                               jnp.float32),
                "conv": jnp.zeros((P, n_slabs, cfg.ssm_d_conv - 1, di),
                                  dtype)}
    if mixer == "mlstm":
        di = cfg.ssm_expand * cfg.d_model
        nh = cfg.lstm_heads
        dh = di // nh
        return {"C": jnp.zeros((P, n_slabs, nh, dh, dh), jnp.float32),
                "n": jnp.zeros((P, n_slabs, nh, dh), jnp.float32),
                "m": jnp.zeros((P, n_slabs, nh), jnp.float32),
                "conv": jnp.zeros((P, n_slabs, cfg.ssm_d_conv - 1, di),
                                  dtype)}
    if mixer == "slstm":
        nh = cfg.lstm_heads
        dh = cfg.d_model // nh
        return {k: jnp.zeros((P, n_slabs, nh, dh), jnp.float32)
                for k in ("c", "n", "m", "h")}
    raise ValueError(slot)
