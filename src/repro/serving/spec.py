"""Prompt-lookup speculative drafting: a model-free n-gram proposer.

Speculative decoding amortizes one pipelined forward pass over several
tokens — the serving analogue of the paper's multi-stage MAC pipelining,
where throughput comes from keeping the array busy per pass, not from
more passes. The classic scheme needs a second (small) draft model; the
**prompt-lookup** variant (PLD) replaces it with an n-gram index over the
sequence's own history (prompt + generated tokens): when the tail of the
history has occurred before, propose the tokens that followed it last
time. Repetition-heavy workloads — code editing, extraction, RAG with
quoted context, and the degenerate loops small models fall into — hand
this drafter long correct continuations for free; on novel text it simply
proposes nothing and the engine decodes one token per pass as before.

Correctness never depends on the drafter: the engine verifies every
proposal against the target model in a single multi-token forward pass
(``models/lm.lm_paged_verify``) and keeps only the longest accepted
prefix, so a bad proposal costs wasted window compute, never a wrong
token (``docs/SERVING.md`` — speculative decoding).

The index is incremental and O(ngrams) per appended token: ``start`` a
sequence with its prompt, ``extend`` it with each *emitted* token
(rejected draft tokens must never enter the history), ``propose`` reads
the index, ``drop`` frees the sequence. Host-side and deterministic —
nothing here touches the device.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PromptLookupDrafter"]

#: draft-window length the engine uses when the caller doesn't pass one;
#: REPRO_SPEC_K=N overrides (serving/engine.py reads it).
DEFAULT_SPEC_K = 4


@dataclasses.dataclass
class _SeqState:
    history: list           # prompt + emitted tokens, in order
    # per n: n-gram tuple -> position right after its latest *interior*
    # occurrence (the continuation start). The gram ending at the current
    # tail is indexed only once its continuation token exists, so a
    # lookup can never point past the end of the history.
    index: dict


class PromptLookupDrafter:
    """Per-sequence n-gram index over prompt + output.

    ``ngram_max`` down to ``ngram_min`` are tried in order at proposal
    time — longer grams give higher-precision matches, the 1-gram floor
    catches the constant runs that dominate greedy decode on repetitive
    text. Ties between occurrences resolve to the **latest** one (the
    index keeps one continuation per gram), which tracks locally
    repeating structure better than the first occurrence would.
    """

    def __init__(self, *, ngram_max: int = 3, ngram_min: int = 1):
        if not 1 <= ngram_min <= ngram_max:
            raise ValueError(
                f"need 1 <= ngram_min <= ngram_max, got "
                f"({ngram_min}, {ngram_max})")
        self.ngram_max = ngram_max
        self.ngram_min = ngram_min
        self._seqs: dict[int, _SeqState] = {}

    # -- lifecycle -----------------------------------------------------------

    def start(self, seq_id: int, prompt) -> None:
        """Begin tracking a sequence; index every n-gram of its prompt."""
        if seq_id in self._seqs:
            raise KeyError(f"seq {seq_id} already tracked")
        st = _SeqState(history=[],
                       index={n: {} for n in range(self.ngram_min,
                                                   self.ngram_max + 1)})
        self._seqs[seq_id] = st
        for t in np.asarray(prompt).tolist():
            self._append(st, int(t))

    def extend(self, seq_id: int, token: int) -> None:
        """Append one *emitted* token (accepted draft, correction or bonus
        — never a rejected draft) and index the grams it completes."""
        self._append(self._seqs[seq_id], int(token))

    def drop(self, seq_id: int) -> None:
        """Forget a finished sequence (missing ids are fine — the dense
        fallback paths never start one)."""
        self._seqs.pop(seq_id, None)

    def _append(self, st: _SeqState, token: int) -> None:
        # the grams ENDING at the previous tail become interior (their
        # continuation — this token — now exists), so index them now;
        # `pos` is where the continuation starts, always < len(history)
        pos = len(st.history)
        for n in range(self.ngram_min, self.ngram_max + 1):
            if pos >= n:
                st.index[n][tuple(st.history[pos - n:pos])] = pos
        st.history.append(token)

    # -- proposal ------------------------------------------------------------

    def propose(self, seq_id: int, k: int) -> list[int]:
        """Up to ``k`` draft tokens continuing the sequence's tail, from
        the latest earlier occurrence of the longest matching tail
        n-gram. Empty when the tail is novel (or ``k < 1``) — the engine
        then runs a plain single-token window."""
        if k < 1:
            return []
        st = self._seqs[seq_id]
        hist = st.history
        for n in range(self.ngram_max, self.ngram_min - 1, -1):
            if len(hist) < n:
                continue
            pos = st.index[n].get(tuple(hist[len(hist) - n:]))
            if pos is not None:
                return hist[pos:pos + k]
        return []
