"""Data-parallel replica routing on top of the serving engine.

``ReplicaRouter`` owns N independent ``ServeEngine`` replicas of the same
model — the data-parallel tier above tensor parallelism. Each replica
gets its own state cache with a **per-replica page budget** (an explicit
``pool_pages``/``host_pages``/``prefix_cache_pages`` total is split
across replicas; the defaults are already per-replica) and, when the
config also shards (``shards > 1``) and enough devices exist, its own
**disjoint device slice** — replica i runs on devices
``[i*shards, (i+1)*shards)``, so replicas never contend for a chip.

Requests route at submit time to the least-loaded replica (queued +
resident, ties to the lowest index — deterministic, so a replayed
request wave lands identically). The router mirrors the engine's public
surface (``submit`` / ``step`` / ``run`` / ``has_work`` / ``stream`` /
``cancel`` / ``metrics`` / ``reset_metrics``); per-rid calls route
through the submit-time map, and ``metrics()`` merges the fleet: summed
counters, latency/TTFT percentiles recomputed over the union of finished
requests (NOT averaged per-replica percentiles — those aren't
percentiles of anything), fleet-total peak bytes, and the untouched
per-replica dicts under ``"per_replica"``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.runtime import Runtime
from repro.serving.config import ServeConfig
from repro.serving.engine import Request, ServeEngine

__all__ = ["ReplicaRouter"]


def _split_budget(total: Optional[int], n: int) -> Optional[int]:
    """An explicit pool total split across n replicas (>= 1 each); None
    (engine-derived default) is already per-replica."""
    if total is None:
        return None
    return max(1, total // n)


class ReplicaRouter:
    def __init__(self, params, cfg: ArchConfig,
                 config: ServeConfig | None = None, *,
                 rt: Runtime | None = None, devices=None):
        sc = (config or ServeConfig()).resolve(cfg)
        self.cfg = cfg
        self.config = sc
        self.replicas = sc.replicas
        per_replica = sc.replace(
            replicas=1,
            pool_pages=_split_budget(sc.pool_pages, sc.replicas),
            host_pages=_split_budget(sc.host_pages, sc.replicas),
            prefix_cache_pages=_split_budget(sc.prefix_cache_pages,
                                             sc.replicas))
        if devices is not None:
            devs = list(devices)
        else:
            import jax
            devs = list(jax.devices())
        self.engines: list[ServeEngine] = []
        for i in range(sc.replicas):
            if sc.shards > 1:
                lo = i * sc.shards
                if lo + sc.shards > len(devs):
                    raise ValueError(
                        f"replicas={sc.replicas} x shards={sc.shards} "
                        f"needs {sc.replicas * sc.shards} devices, have "
                        f"{len(devs)} — on CPU set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=N "
                        "(repro.launch.hostdev)")
                slice_ = devs[lo:lo + sc.shards]
            else:
                slice_ = None       # single-device replicas share placement
            self.engines.append(ServeEngine(params, cfg, per_replica,
                                            rt=rt, devices=slice_))
        self._rid_replica: dict[int, int] = {}

    # -- routing -------------------------------------------------------------

    def _load(self, eng: ServeEngine) -> int:
        return len(eng.queue) + sum(r is not None for r in eng.slot_req)

    def submit(self, req: Request) -> int:
        """Route to the least-loaded replica (deterministic tie-break).
        Returns the replica index the request landed on."""
        if req.rid in self._rid_replica:
            # each engine checks its own in-flight/finished rids; the
            # router must catch the cross-replica collision they can't
            raise ValueError(
                f"request id {req.rid} already routed to replica "
                f"{self._rid_replica[req.rid]}")
        idx = min(range(self.replicas),
                  key=lambda i: (self._load(self.engines[i]), i))
        self.engines[idx].submit(req)
        self._rid_replica[req.rid] = idx
        return idx

    def _engine_for(self, rid: int) -> ServeEngine:
        idx = self._rid_replica.get(rid)
        if idx is None:
            raise KeyError(f"request {rid}: unknown rid (never routed)")
        return self.engines[idx]

    # -- engine surface ------------------------------------------------------

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def step(self):
        """One tick on every replica with live work (arrival-process
        drivers interleave this with submit())."""
        for e in self.engines:
            if e.has_work():
                e.step()

    @property
    def finished(self):
        """Merged finished list in completion-time order."""
        done = [r for e in self.engines for r in e.finished]
        done.sort(key=lambda r: r.t_done)
        return done

    def run(self, max_steps: int = 10_000, *, strict: bool = True):
        """Drain every replica; replicas are independent (no shared
        cache), so they drain sequentially. Returns the merged finished
        list in completion-time order."""
        for e in self.engines:
            if e.has_work():
                e.run(max_steps, strict=strict)
        return self.finished

    def stream(self, rid: int):
        return self._engine_for(rid).stream(rid)

    def cancel(self, rid: int) -> bool:
        return self._engine_for(rid).cancel(rid)

    def reset_metrics(self):
        for e in self.engines:
            e.reset_metrics()
        # keep only rids still live somewhere (mirrors the engines'
        # stream-state pruning, so stream()/cancel() stay routable)
        self._rid_replica = {rid: i for rid, i in self._rid_replica.items()
                             if rid in self.engines[i]._streams}

    # -- merged metrics ------------------------------------------------------

    _SUM_KEYS = ("requests_finished", "requests_cancelled",
                 "tokens_generated", "engine_steps", "model_calls",
                 "wall_s", "undrained_runs", "peak_kv_bytes",
                 "peak_state_bytes")

    def metrics(self) -> dict:
        """Fleet view: summed counters, percentiles recomputed over the
        union of finished requests, per-replica dicts under
        ``per_replica``."""
        per = [e.metrics() for e in self.engines]
        out: dict = {"replicas": self.replicas,
                     "shards": self.config.shards,
                     "requests_per_replica":
                         [len(e.finished) for e in self.engines]}
        for k in self._SUM_KEYS:
            out[k] = type(per[0][k])(sum(m[k] for m in per))
        wall = out["wall_s"]
        out["tokens_per_s"] = (out["tokens_generated"] / wall
                               if wall else 0.0)
        fin = [r for e in self.engines for r in e.finished]
        lat = [r.t_done - r.t_enqueue for r in fin]
        ttft = [r.t_first_token - r.t_enqueue for r in fin]
        out["ttft_p50_ms"] = 1e3 * float(np.median(ttft)) if ttft else 0.0
        out["ttft_p95_ms"] = (1e3 * float(np.percentile(ttft, 95))
                              if ttft else 0.0)
        out["latency_p50_ms"] = 1e3 * float(np.median(lat)) if lat else 0.0
        out["latency_p95_ms"] = (1e3 * float(np.percentile(lat, 95))
                                 if lat else 0.0)
        out["per_replica"] = per
        return out
