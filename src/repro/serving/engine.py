"""Batched serving engine: continuous-batching slots over the compiled
prefill/decode steps, with SPx-quantized weights (the paper's deployment
mode). Single-host execution here; the distributed dry-run exercises the
same step functions on the production meshes.

Requests enter a queue; the engine packs up to ``batch_slots`` active
sequences, prefills new arrivals (padded to the slot length), then decodes
in lockstep — one logits row per active slot per step, greedy or
temperature sampling. Finished sequences release their slot.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm as lm_mod
from repro.nn.layers import quantize_params
from repro.runtime import Runtime

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, params, cfg: ArchConfig, *, batch_slots: int = 4,
                 max_seq: int = 256, quantize: str | None = "sp2_4",
                 rt: Runtime | None = None, seed: int = 0):
        self.cfg = cfg
        self.rt = rt or Runtime(impl="auto", q_chunk=256)
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        if quantize:
            params = quantize_params(params, quantize)
        self.params = params
        self._key = jax.random.PRNGKey(seed)

        # cfg and rt are frozen/hashable and ride as *static* jit arguments:
        # an engine whose Runtime is replaced by an equal-valued copy reuses
        # the compiled steps (no retrace — tests/test_runtime.py)
        self._decode = jax.jit(lm_mod.lm_decode_step, static_argnums=(4, 5),
                               donate_argnums=(3,))
        # per-slot position prefill: tokens padded to max_prompt, true
        # lengths masked; logits of the last real token are picked host-side
        self._prefill_one = jax.jit(lm_mod.lm_prefill,
                                    static_argnums=(3, 4))
        self.caches = lm_mod.init_caches(cfg, batch_slots, max_seq,
                                         dtype=jnp.float32)
        self.slot_req: list[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int64)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    # -- public API ----------------------------------------------------------

    def submit(self, req: Request):
        req.t_enqueue = time.time()
        self.queue.append(req)

    def run(self, max_steps: int = 10_000):
        """Drive until queue + slots drain (or step limit)."""
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self._admit()
            self._decode_step()
        return self.finished

    # -- internals -------------------------------------------------------------

    def _admit(self):
        for slot in range(self.batch_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[slot] = req
                # prefill this slot: run prompt through a single-row batch,
                # then splice its caches into the engine batch at `slot`
                tok = jnp.asarray(req.prompt, jnp.int32)[None, :]
                row_caches = lm_mod.init_caches(self.cfg, 1, self.max_seq,
                                                dtype=jnp.float32)
                logits, row_caches = self._prefill_one(self.params, tok,
                                                       row_caches, self.cfg,
                                                       self.rt)
                self.caches = _splice_caches(self.caches, row_caches, slot)
                self.slot_pos[slot] = len(req.prompt)
                first = self._pick_token(logits[0], req)
                req.output.append(int(first))
                req.t_first_token = time.time()

    def _decode_step(self):
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tokens = np.zeros(self.batch_slots, np.int32)
        for i in active:
            tokens[i] = self.slot_req[i].output[-1]
        # continuous batching: each slot decodes at its own position
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.caches = self._decode(self.params,
                                           jnp.asarray(tokens),
                                           pos, self.caches, self.cfg,
                                           self.rt)
        logits = np.asarray(logits)
        for i in active:
            req = self.slot_req[i]
            tok = self._pick_token(logits[i], req)
            req.output.append(int(tok))
            self.slot_pos[i] += 1
            if (len(req.output) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.max_seq - 1):
                req.done = True
                req.t_done = time.time()
                self.finished.append(req)
                self.slot_req[i] = None

    def _pick_token(self, row: np.ndarray, req: Request) -> int:
        if req.temperature <= 0:
            return int(np.argmax(row))
        self._key, sub = jax.random.split(self._key)
        return int(jax.random.categorical(sub, jnp.asarray(row)
                                          / req.temperature))


def _splice_caches(batch_caches, row_caches, slot: int):
    """Insert a prefilled single-row cache at batch index ``slot``. Cache
    leaves have layout (P, B, ...)."""
    def splice(bc, rc):
        return bc.at[:, slot:slot + 1].set(rc)
    return jax.tree_util.tree_map(splice, batch_caches, row_caches)
