"""Batched serving engine over the compiled step functions, with
SPx-quantized weights (the paper's deployment mode). Single-host execution
here; the distributed dry-run exercises the same step functions on the
production meshes.

Two KV layouts (docs/SERVING.md has the full lifecycle):

* **paged** (the default for every architecture): serving state lives in
  a unified **StateCache** (serving/kv_cache.py) with three regions under
  one budget — token-paged KV for attn/xdec mixers, fixed-size **slabs**
  of recurrent state for SSM mixers (mamba/mlstm/slstm — one slab per
  live sequence covering every SSM slot x period), and a read-only
  shared **cross** region holding encoder-output K/V keyed by a frames
  hash (enc-dec: repeated inputs reuse the whole encoder pass). The
  layer pattern is the routing unit: jamba's attention layers page while
  its mamba layers slab; pure-SSM patterns run pageless. Admission is
  all-or-nothing across regions — a request is admitted when the cache
  covers its worst-case footprint, otherwise it waits in the queue.
  Prompts stream through **chunked prefill** (planner/env-sized chunks,
  one chunk per engine tick per slot, interleaved with decode steps of
  already-running sequences), and decode attends through the block table
  via the paged-attention kernel. Memory scales with tokens + sequences
  in flight, not ``batch_slots x max_seq``.

* **dense**: the original per-slot ``(B, Hkv, max_seq, dh)`` cache (plus
  per-slot recurrent state / cross-KV blocks where the pattern has
  them); prompts pad to the slot length at admission and decode runs in
  lockstep. Kept as the differential-test baseline for every
  architecture.

The paged layout optionally shares KV pages across requests
(``prefix_cache=True`` / ``--prefix-cache`` / ``REPRO_PREFIX_CACHE=1``):
admission matches the prompt against the pool's prefix index, maps the
matched full pages into the sequence's page list (refcount bump, zero
prefill work), chunk-prefills only the unmatched tail, and copy-on-writes
the final matched page when the whole prompt is page-aligned-identical
(the last prompt token must be re-run for logits and would otherwise
write into a shared page). Greedy outputs are identical with sharing on
or off (regression-tested) — sharing changes where bytes live, never
what they hold.

The paged layout also supports **speculative decoding**
(``spec_decode=True`` / ``--spec-decode`` / ``REPRO_SPEC_K=N``): a
model-free prompt-lookup drafter (serving/spec.py) proposes up to K
tokens per decoding slot from the sequence's own n-gram history, one
batched verify pass scores the whole K+1 window against the paged cache
(``models/lm.lm_paged_verify``), and the engine keeps the longest
accepted prefix plus one bonus/correction token — rolling the KV write
cursor back past any rejected tail. Greedy outputs are identical with
speculation on or off (regression-tested); what changes is model calls
per emitted token (``metrics()["model_calls"]``,
``accepted_per_step``, ``draft_acceptance_rate``).

Either layout composes with the quantized KV cache (``rt.kv_quant`` +
``rt.kv_scheme`` — uniform8 baseline or non-uniform SPx): paged pools
store uint8 codes + per-token scale and decode through the fused-dequant
paged-attention kernel; page/pool byte accounting follows the layout
actually allocated (``kv_cache_dtype``, or codes+scale when quantized).

Both layouts produce identical greedy outputs (regression-tested); the
engine exposes throughput/occupancy metrics either way via ``metrics()``.

Sampling (``temperature > 0``) draws from a per-request PRNG chain
(``Request.seed``, default derived from the engine seed and the rid), so
a sampled request's output is a function of the request alone — not of
submit order or which other requests share the batch.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.mesh import make_serving_mesh
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.nn.layers import quantize_params
from repro.runtime import Runtime, planner
from repro.serving.config import LEGACY_KNOBS, ServeConfig
from repro.serving.kv_cache import (StateCache, cross_kv_bytes_per_seq,
                                    kv_bytes_per_token,
                                    ssm_state_bytes_per_seq)
from repro.serving.spec import PromptLookupDrafter
from repro.serving.stream import StreamState, TokenStream
from repro.sharding import ShardingPolicy

__all__ = ["Request", "ServeConfig", "ServeEngine"]

#: every engine timestamp (t_enqueue / t_first_token / t_done, wall
#: accounting) comes through this hook. It must be a *monotonic* clock:
#: TTFT and latency are differences of these stamps, and wall-clock
#: ``time.time()`` can step backwards under NTP adjustment, turning a
#: latency percentile negative. Module-level so the fake-clock
#: regression test can monkeypatch it.
_now = time.monotonic


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    #: sampling seed (temperature > 0). None derives a key from the
    #: engine seed and the rid, so two engines with the same seed agree;
    #: either way every draw comes from this request's own key chain —
    #: sampled outputs cannot depend on submit order or batch-mates.
    seed: Optional[int] = None
    #: SLA class for the continuous-batching scheduler: higher values
    #: admit first and may preempt strictly-lower-priority residents;
    #: ties break FIFO by submit order. The FIFO scheduler ignores it.
    priority: int = 0
    #: encoder input for enc-dec models: (S_enc, D) frame embeddings
    #: (the audio conv frontend is stubbed upstream). Required when
    #: cfg.enc_dec; identical frames across requests share one encoded
    #: cross-KV entry in the state cache's cross region.
    frames: Optional[np.ndarray] = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    preemptions: int = 0            # times this request was preempted
    key: object = dataclasses.field(default=None, repr=False)
    # scheduler internals: submit-order tiebreak, and the (write cursor,
    # prefill progress) pair a preempted request resumes from
    _seq: int = dataclasses.field(default=0, repr=False)
    _resume: object = dataclasses.field(default=None, repr=False)


def _pad_pow2(n: int, cap: int) -> int:
    """Smallest power of two >= n, capped (chunk-padding bucket)."""
    return min(cap, 1 << max(0, (n - 1)).bit_length())


class ServeEngine:
    def __init__(self, params, cfg: ArchConfig,
                 config: ServeConfig | None = None, *,
                 rt: Runtime | None = None, devices=None, **legacy):
        # one-PR migration shim: the old per-knob keyword arguments are
        # still accepted, forwarded into a ServeConfig with a
        # DeprecationWarning. ServeConfig is the sole knob path.
        if config is not None and not isinstance(config, ServeConfig):
            raise TypeError(
                f"config must be a ServeConfig, got {type(config).__name__}")
        if legacy:
            unknown = sorted(set(legacy) - LEGACY_KNOBS)
            if unknown:
                raise TypeError(
                    f"ServeEngine() got unexpected keyword argument(s) "
                    f"{unknown} — serving knobs live on ServeConfig")
            warnings.warn(
                "passing serving knobs as ServeEngine keyword arguments "
                "is deprecated — construct a ServeConfig instead: "
                f"ServeEngine(params, cfg, ServeConfig({', '.join(sorted(legacy))}))",
                DeprecationWarning, stacklevel=2)
            config = (config or ServeConfig()).replace(**legacy)
        self.cfg = cfg
        self.rt = rt or Runtime(impl="auto", q_chunk=256)
        # ALL env fallback + cross-knob validation happens here, nowhere
        # else in the engine (docs/SERVING.md "ServeConfig")
        sc = (config or ServeConfig()).resolve(cfg)
        self.config = sc
        if sc.replicas > 1:
            raise ValueError(
                f"replicas={sc.replicas} is a router knob — build a "
                "repro.serving.ReplicaRouter for data-parallel replicas "
                "(a bare ServeEngine is always one replica)")
        self.batch_slots = sc.batch_slots
        self.max_seq = sc.max_seq
        self.kv_cache_dtype = jnp.dtype(sc.kv_cache_dtype)
        self.kv_layout = sc.kv_layout
        self.prefix_cache = sc.prefix_cache
        self.spec_k = sc.spec_k
        self.fused_decode = sc.fused_decode
        self.scheduler = sc.scheduler
        self.host_pages = sc.host_pages
        self.prefix_cache_pages = sc.prefix_cache_pages
        self.shards = sc.shards
        # KV quantization (scheme-parameterized, docs/QUANTIZATION.md):
        # whenever rt.kv_quant is set the cache layout is uint8 codes +
        # f32 scale and kv_cache_dtype is IGNORED by the cache allocators
        # (metrics() then reports the layout, not the dtype arg)
        self.kv_scheme = self.rt.kv_scheme if self.rt.kv_quant else None
        if sc.quantize:
            params = quantize_params(params, sc.quantize)
        self.params = params
        # base for per-request sampling keys (Request.seed overrides)
        self._base_key = jax.random.PRNGKey(sc.seed)

        # layer pattern is the routing unit for the unified state cache:
        # attn/xdec mixers page token KV, SSM mixers (mamba/mlstm/slstm)
        # pin one fixed-size slab per live sequence, enc-dec adds a
        # read-only shared cross region. For enc-dec models the DECODER
        # pattern is what holds serving state.
        self._decode_cfg = encdec_mod.dec_cfg(cfg) if cfg.enc_dec else cfg
        mixers = {s.split("+")[0] for s in self._decode_cfg.pattern}
        self._has_pages = bool(mixers & {"attn", "xdec"})
        self._has_slab = bool(mixers & {"mamba", "mlstm", "slstm"})
        self._has_cross = bool(cfg.enc_dec)

        # tensor parallelism (shards > 1): build a (data=1, model=shards)
        # mesh over an explicit device slice, place params by the same
        # ShardingPolicy the dry-run uses (Megatron TP), head-shard the
        # paged pools, and thread the mesh through Runtime so the forward
        # passes plant their sharding constraints. GSPMD partitions the
        # jitted steps; block tables and token batches stay replicated.
        self.mesh = None
        self._policy = None
        if sc.shards > 1:
            devs = (list(devices) if devices is not None
                    else list(jax.devices()))
            if len(devs) < sc.shards:
                raise ValueError(
                    f"shards={sc.shards} needs at least {sc.shards} "
                    f"devices, have {len(devs)} — on CPU set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                    "before jax initializes (repro.launch.hostdev)")
            self.mesh = make_serving_mesh(model=sc.shards,
                                          devices=devs[:sc.shards])
            self.rt = self.rt.replace(mesh=self.mesh, model_axis="model",
                                      data_axes=("data",))
            self._policy = ShardingPolicy(cfg, self.mesh, fsdp=False,
                                          parallelism="tp")
            self.params = jax.device_put(
                self.params,
                self._policy.named(self._policy.param_specs(self.params)))

        self.slot_req: list[Optional[Request]] = [None] * self.batch_slots
        self.slot_pos = np.zeros(self.batch_slots, np.int64)  # tokens held
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        # per-rid delivery state (serving/stream.py): created at submit,
        # terminal at finish/cancel/error; stream() hands out views
        self._streams: dict[int, StreamState] = {}
        self._cancelled = 0
        self._occ_samples: list[float] = []
        self._tokens_out = 0
        self._steps = 0
        self._wall = 0.0
        # jitted forward passes issued (prefill chunks + decode/verify
        # steps) — the quantity speculation shrinks per emitted token
        self._model_calls = 0
        # speculation counters: per-row windows that carried >= 1 draft
        # (a batched verify call holds one window per drafted slot),
        # and drafts proposed/accepted across them
        self._spec_windows = 0
        self._spec_proposed = 0
        self._spec_accepted = 0
        # scheduler counters: preempt/resume traffic and undrained runs
        self._preemptions = 0
        self._resumes = 0
        self._offload_bytes = 0
        self._onload_bytes = 0
        self._undrained_runs = 0
        self._submit_seq = 0
        #: did the last run() drain every request? (satellite of the
        #: old silent-truncation bug: stopping at max_steps with live
        #: work now raises under strict=True and flips this flag)
        self.drained = True

        if sc.kv_layout == "paged":
            self._init_paged()
        else:
            self._init_dense()
        if self.mesh is not None:
            # head-shard the paged KV/cross pools over the model axis
            # (replicated where Hkv doesn't divide); slabs replicate.
            # Committing the initial placement is enough — the donated
            # cache argument keeps whatever sharding GSPMD settles on.
            specs = self._policy.paged_state_specs(self.caches)
            self.caches = jax.device_put(self.caches,
                                         self._policy.named(specs))

    def _slab_mixers(self) -> list[str]:
        """The recurrent mixer kinds present in the decode pattern."""
        return sorted({s.split("+")[0] for s in self._decode_cfg.pattern}
                      & {"mamba", "mlstm", "slstm"})

    def _paged_layers(self) -> int:
        """Layer-slot count of the token-KV page pools (the pools'
        leading dim — what one page spans byte-wise)."""
        n = 0
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.caches):
            keys = [str(getattr(k, "key", getattr(k, "name", "")))
                    for k in path]
            if "kp" in keys:
                n = max(n, int(leaf.shape[0]))
        return max(n, 1)

    # -- layout-specific setup ----------------------------------------------

    def _init_dense(self):
        # cfg and rt are frozen/hashable and ride as *static* jit arguments:
        # an engine whose Runtime is replaced by an equal-valued copy reuses
        # the compiled steps (no retrace — tests/test_runtime.py)
        if self.cfg.enc_dec:
            self._decode = jax.jit(encdec_mod.encdec_decode_step,
                                   static_argnums=(4, 5),
                                   donate_argnums=(3,))
            # encoder + decoder-prompt prefill, one request at a time;
            # frames ride as the extra leading array argument
            self._prefill_one = jax.jit(encdec_mod.encdec_prefill,
                                        static_argnums=(4, 5))
            self.caches = encdec_mod.encdec_init_caches(
                self.cfg, self.batch_slots, self.max_seq,
                dtype=self.kv_cache_dtype, kv_quant=self.rt.kv_quant)
            return
        self._decode = jax.jit(lm_mod.lm_decode_step, static_argnums=(4, 5),
                               donate_argnums=(3,))
        # per-slot position prefill: tokens padded to max_prompt, true
        # lengths masked; logits of the last real token are picked host-side
        self._prefill_one = jax.jit(lm_mod.lm_prefill,
                                    static_argnums=(3, 4))
        self.caches = lm_mod.init_caches(self.cfg, self.batch_slots,
                                         self.max_seq,
                                         dtype=self.kv_cache_dtype,
                                         kv_quant=self.rt.kv_quant)

    def _init_paged(self):
        cfg = self.cfg
        dcfg = self._decode_cfg
        sc = self.config
        if self._has_pages:
            rep = dcfg.n_heads // dcfg.n_kv_heads
            plan = planner.plan_kv_pages(
                dcfg.n_kv_heads, dcfg.dh, rep=rep,
                act_bytes=self.kv_cache_dtype.itemsize,
                kv_scheme=self.kv_scheme)
            self.page_size = min(sc.page_size or plan.page_size,
                                 self.max_seq)
            self.pages_per_seq = -(-self.max_seq // self.page_size)
            # default pool = the dense engine's worst case, so
            # paged-vs-dense comparisons start from equal budgets; pass a
            # smaller pool to get admission backpressure
            # (tests/test_serving.py exercises this)
            n_pages = sc.pool_pages or self.batch_slots * self.pages_per_seq
        else:
            # pageless (pure-SSM pattern): no mixer writes token KV, the
            # pool degenerates to the slab region only
            self.page_size = 1
            self.pages_per_seq = 0
            n_pages = 0
        # one slab per live sequence covers every SSM slot x period; one
        # cross entry per live *distinct input* (shared across sequences)
        self._n_slabs = self.batch_slots if self._has_slab else 0
        self._n_cross = self.batch_slots if self._has_cross else 0
        self.pool = StateCache(n_pages, self.page_size,
                               n_slabs=self._n_slabs,
                               n_cross=self._n_cross,
                               host_pages=self.host_pages,
                               cache_pages=self.prefix_cache_pages)
        # env fallback + validation happened in ServeConfig.resolve()
        self.prefill_chunk = sc.prefill_chunk
        if cfg.enc_dec:
            self.caches = encdec_mod.encdec_paged_init_caches(
                cfg, self.pool.n_pages, self.page_size,
                dtype=self.kv_cache_dtype, kv_quant=self.rt.kv_quant,
                n_slabs=self._n_slabs, n_cross=self._n_cross)
            step_fn = encdec_mod.encdec_paged_step
            verify_fn = encdec_mod.encdec_paged_verify
            fused_fn = encdec_mod.encdec_paged_fused_step
        else:
            self.caches = lm_mod.paged_init_caches(
                cfg, self.pool.n_pages, self.page_size,
                dtype=self.kv_cache_dtype, kv_quant=self.rt.kv_quant,
                n_slabs=self._n_slabs, n_cross=self._n_cross)
            step_fn = lm_mod.lm_paged_step
            verify_fn = lm_mod.lm_paged_verify
            fused_fn = lm_mod.lm_paged_fused_step
        self._paged_step = jax.jit(step_fn, static_argnums=(7, 8),
                                   donate_argnums=(6,))
        if self.fused_decode:
            # decode megakernel tick: ONE compiled function serves both
            # tick shapes — plain decode (W == 1) and the spec verify
            # window (W == spec_k + 1) — and inside it every layer's
            # attention is one paged_decode_ragged launch
            self._fused_step = jax.jit(fused_fn, static_argnums=(7, 8),
                                       donate_argnums=(6,))
        if self.spec_k:
            if not self.fused_decode:
                # multi-token verify: same paged step, logits at every
                # window position; one compile serves every tick (fixed
                # K+1 window, ragged rows ride on n_valid like prefill
                # chunks do). The fused path scores windows through
                # _fused_step instead.
                self._paged_verify = jax.jit(verify_fn,
                                             static_argnums=(7, 8),
                                             donate_argnums=(6,))
            self.drafter = PromptLookupDrafter()
        # copy-on-write page duplication; src/dst ride as traced scalars
        # so the one compile covers every page pair
        self._copy_page = jax.jit(lm_mod.paged_copy_page,
                                  donate_argnums=(0,))
        # preemption snapshot/restore: whole-page gather to host and
        # scatter back. Page-index vectors are traced and pow2-padded, so
        # O(log pages_per_seq) compiles cover every preemption shape.
        self._gather_pages = jax.jit(lm_mod.paged_gather_pages)
        self._scatter_pages = jax.jit(lm_mod.paged_scatter_pages,
                                      donate_argnums=(0,))
        if self._has_slab:
            # slab snapshot/restore (preemption) and the fresh-admission
            # zero; the slab index rides as a traced scalar
            self._gather_slabs = jax.jit(lm_mod.paged_gather_slabs)
            self._scatter_slabs = jax.jit(lm_mod.paged_scatter_slabs,
                                          donate_argnums=(0,))
            self._reset_slabs = jax.jit(lm_mod.paged_reset_slabs,
                                        donate_argnums=(0,))
        if self._has_cross:
            # encoder pass + per-slot cross-KV projection, run once per
            # DISTINCT frames (the cross region shares entries by key)
            self._encode_cross = jax.jit(encdec_mod.encdec_cross_kv,
                                         static_argnums=(2, 3))
            self._fill_cross = jax.jit(lm_mod.paged_fill_cross,
                                       donate_argnums=(0,))
        # per-slot (slab, cross) indices for the step functions;
        # out-of-range sentinels mean "no slab / no cross entry"
        self._state_idx = np.tile(
            np.array([self._n_slabs, self._n_cross], np.int32),
            (self.batch_slots, 1))
        self.block_tables = np.zeros(
            (self.batch_slots, max(self.pages_per_seq, 1)), np.int32)
        # per-slot prefill progress: tokens of the prompt already fed;
        # -1 means the slot is decoding
        self._fed = np.full(self.batch_slots, -1, np.int64)
        # frames hash per in-flight rid (cross-region key), computed once
        self._frames_keys: dict[int, bytes] = {}
        # prefix-cache work counters (metrics(); reset_metrics() zeroes)
        self._prefix_hits = 0
        self._prefill_skipped = 0
        self._cow_copies = 0
        # per-request chain keys, hashed once at first admission attempt
        # and reused by every retry tick and prefill-chunk registration
        self._prompt_keys: dict[int, list[bytes]] = {}

    # -- public API ----------------------------------------------------------

    @staticmethod
    def _worst_case_tokens(req: Request) -> int:
        """Tokens the sequence can ever hold — admission reserves this."""
        return len(req.prompt) + req.max_new_tokens

    def _frames_key(self, req: Request) -> bytes | None:
        """Content hash of the request's frames — the cross-region key.
        Identical frames hash equal, so concurrent requests decoding the
        same input share one encoded entry. Cached per rid: admission
        retries must not re-hash 1500-frame inputs every tick."""
        if not self._has_cross:
            return None
        key = self._frames_keys.get(req.rid)
        if key is None:
            f = np.ascontiguousarray(req.frames, np.float32)
            h = hashlib.blake2b(f.tobytes(), digest_size=16)
            h.update(repr(f.shape).encode())
            key = self._frames_keys[req.rid] = h.digest()
        return key

    def _sync_state_idx(self, slot: int, rid: int):
        """Point the slot's (slab, cross) row at the pool's current
        assignment (sentinels where the pattern has no such region)."""
        slab = self.pool.seq_slab(rid)
        cross = self.pool.seq_cross(rid)
        self._state_idx[slot, 0] = self._n_slabs if slab is None else slab
        self._state_idx[slot, 1] = (self._n_cross if cross is None
                                    else cross)

    def _set_block_row(self, slot: int, rid: int):
        if self.pages_per_seq:
            self.block_tables[slot] = self.pool.block_table_row(
                rid, self.pages_per_seq)

    def submit(self, req: Request):
        if len(req.prompt) < 1 or req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: needs a non-empty prompt and "
                f"max_new_tokens >= 1 (got {len(req.prompt)}, "
                f"{req.max_new_tokens})")
        if self.cfg.enc_dec and req.frames is None:
            raise ValueError(
                f"request {req.rid}: {self.cfg.name} is enc-dec — every "
                "request needs frames=(S_enc, D) encoder input")
        if not self.cfg.enc_dec and req.frames is not None:
            raise ValueError(
                f"request {req.rid}: frames= given but {self.cfg.name} "
                "has no encoder")
        if self._worst_case_tokens(req) > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds "
                f"max_seq={self.max_seq}")
        if req.done or req.output:
            # a served Request object is not reusable: its PRNG key chain
            # has advanced past every draw it made, and t_first_token /
            # t_done / preemptions hold the previous run's values —
            # resubmitting it would silently produce a different sampled
            # output and corrupt every latency percentile
            raise ValueError(
                f"request {req.rid}: this Request object was already "
                f"served ({len(req.output)} output token(s), "
                f"done={req.done}) — build a fresh Request per "
                "submission")
        in_flight = ({r.rid for r in self.queue}
                     | {r.rid for r in self.slot_req if r is not None})
        if req.rid in in_flight:
            # rids key the page allocator AND every consumer's results
            # dict; a duplicate would KeyError mid-run (paged) or
            # silently overwrite another request's output (dense)
            raise ValueError(f"request id {req.rid} already in flight")
        if any(r.rid == req.rid for r in self.finished):
            # same key-collision hazard one step later: finished results
            # and stream states are looked up by rid. reset_metrics()
            # clears `finished`, so the benchmark warmup-then-measure
            # pattern stays legal with fresh Request objects.
            raise ValueError(
                f"request id {req.rid} already finished this measurement "
                "window — reuse a rid only after reset_metrics(), and "
                "always with a fresh Request object")
        if self.kv_layout == "paged" and self._has_pages:
            # worst-case reservation (planner-owned model): assume no
            # shared prefix — the index is volatile, so a match visible
            # now may be evicted before this request reaches admission
            need = planner.plan_seq_pages(self._worst_case_tokens(req),
                                          self.page_size)
            if need > self.pool.n_pages:
                # could never be admitted even against an empty pool —
                # reject now instead of busy-spinning run() forever
                raise ValueError(
                    f"request {req.rid}: needs {need} pages but the pool "
                    f"only has {self.pool.n_pages} in total")
        if req.key is None:
            # per-request chain: explicit seed wins; otherwise derive from
            # the engine seed + rid (stable across batch compositions)
            req.key = (jax.random.PRNGKey(req.seed)
                       if req.seed is not None
                       else jax.random.fold_in(self._base_key, req.rid))
        req.t_enqueue = _now()
        req._seq = self._submit_seq
        self._submit_seq += 1
        self._streams[req.rid] = StreamState(req)
        self.queue.append(req)

    def step(self):
        """One public scheduling tick: admission (with preemption under
        the cb scheduler), a prefill chunk per prefilling slot, one decode
        tick. Callers that interleave ``submit`` with engine progress —
        arrival processes in benchmarks, the differential storm tests —
        drive this directly; ``run`` is this in a drain loop."""
        t0 = _now()
        self._tick()
        self._wall += _now() - t0

    def has_work(self) -> bool:
        """Anything queued or resident? The asyncio front-end's
        tick-or-idle signal (and run()'s drain condition)."""
        return bool(self.queue) or any(r is not None
                                       for r in self.slot_req)

    def _tick(self):
        self._steps += 1
        if self.kv_layout == "paged":
            self._admit_paged()
            self._prefill_tick()
            self._decode_step_paged()
            self._occ_samples.append(self.pool.stats.occupancy)
        else:
            self._admit_dense()
            self._decode_step_dense()
            self._occ_samples.append(
                sum(r is not None for r in self.slot_req)
                / self.batch_slots)
        # wake async stream consumers once per tick — every emission of
        # this tick is already in Request.output by now
        for st in self._streams.values():
            st.notify()

    def run(self, max_steps: int = 10_000, *, strict: bool = True):
        """Drive until queue + slots drain (or step limit).

        Hitting ``max_steps`` with live requests used to return silently,
        dropping queued/resident work on the floor. Now it surfaces:
        ``self.drained`` flips False, the ``undrained_runs`` metric
        increments, and under ``strict=True`` (the default) a
        RuntimeError is raised — pass ``strict=False`` to accept the
        partial ``finished`` list instead."""
        t0 = _now()
        for _ in range(max_steps):
            if not self.has_work():
                break
            self._tick()
        self._wall += _now() - t0
        self.drained = not self.has_work()
        if not self.drained:
            self._undrained_runs += 1
            if strict:
                exc = RuntimeError(
                    f"run(max_steps={max_steps}) stopped with live work: "
                    f"{len(self.queue)} queued, "
                    f"{sum(r is not None for r in self.slot_req)} resident "
                    f"({len(self.finished)} finished). Raise max_steps, or "
                    f"pass strict=False to accept partial progress.")
                # streams of the still-live requests get a terminal error
                # state (not a silent hang): pending consumers raise
                # StreamError instead of waiting for tokens that will
                # never come
                self._fail_streams(exc)
                raise exc
        return self.finished

    # -- incremental delivery + cancellation (serving/stream.py) -------------

    def stream(self, rid: int) -> TokenStream:
        """A token iterator over one submitted request. Sync iteration
        drives ``step()`` itself when it runs dry; ``async for`` parks on
        a per-tick wakeup instead (an external loop must tick the
        engine). Every stream sees the full output exactly once — tokens
        are read from ``Request.output`` behind a cursor, so delivery is
        bit-identical to the ``run()`` result by construction. Raises
        KeyError for a rid this engine never saw (or whose terminal
        stream state ``reset_metrics`` already pruned)."""
        st = self._streams.get(rid)
        if st is None:
            raise KeyError(
                f"request {rid}: no stream state (never submitted, or "
                "pruned by reset_metrics())")
        return TokenStream(self, st)

    def cancel(self, rid: int) -> bool:
        """Abort a request mid-flight at any tick boundary and free
        everything it holds — pages/slabs (ref-aware), drafter state,
        prompt/frames keys, and for a preempted-and-parked request its
        host-tier snapshot plus the cross reference offload retained.
        Open streams turn terminal (``StreamCancelled``); the request
        never joins ``finished``. Returns True when live work was
        cancelled, False when the request already reached a terminal
        state (finished, or cancelled before). Raises KeyError for a rid
        this engine never saw."""
        st = self._streams.get(rid)
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                # resident: queued-for-decode, mid-prefill, mid-verify —
                # all hold the same reservation; release() recycles
                # zero-ref pages, the slab, and the cross reference
                self.slot_req[slot] = None
                self.slot_pos[slot] = 0
                if self.kv_layout == "paged":
                    self.pool.release(rid)
                    self.block_tables[slot] = 0
                    self._fed[slot] = -1
                    self._state_idx[slot] = (self._n_slabs, self._n_cross)
                    if self.spec_k:
                        self.drafter.drop(rid)
                self._drop_request(r, st)
                return True
        for r in list(self.queue):
            if r.rid == rid:
                self.queue.remove(r)
                if r._resume is not None:
                    # preempted-and-parked: the pool holds its snapshot
                    # on the host tier (and, enc-dec, its cross ref)
                    self.pool.drop_host(rid)
                    r._resume = None
                self._drop_request(r, st)
                return True
        if st is not None or any(r.rid == rid for r in self.finished):
            return False                # already finished / cancelled
        raise KeyError(f"request {rid}: unknown rid (never submitted)")

    def _drop_request(self, req: Request, st: StreamState | None):
        """Shared tail of both cancel paths: per-rid key caches, the
        terminal stamp, the stream transition, the metric."""
        if self.kv_layout == "paged":
            self._prompt_keys.pop(req.rid, None)
            self._frames_keys.pop(req.rid, None)
        req.done = True
        req.t_done = _now()
        self._cancelled += 1
        if st is not None:
            st.cancel()

    def _fail_streams(self, exc: BaseException):
        """Move every still-live stream to the error state (undrained
        strict run): blocked consumers raise StreamError, never hang."""
        for st in self._streams.values():
            if st.status == "live":
                st.fail(exc)

    def reset_metrics(self):
        """Zero the throughput/latency/occupancy counters (compiled steps
        and cache state are kept). Benchmarks call this between a warmup
        pass — which pays all the jit compiles — and the measured pass."""
        self.finished = []
        self._occ_samples = []
        self._tokens_out = 0
        self._steps = 0
        self._wall = 0.0
        self._model_calls = 0
        self._spec_windows = 0
        self._spec_proposed = 0
        self._spec_accepted = 0
        self._preemptions = 0
        self._resumes = 0
        self._offload_bytes = 0
        self._onload_bytes = 0
        self._undrained_runs = 0
        self._cancelled = 0
        self.drained = True
        # terminal stream states go with the finished list they mirror;
        # live ones (in-flight requests) survive the reset
        self._streams = {rid: st for rid, st in self._streams.items()
                         if st.status == "live"}
        if self.kv_layout == "paged":
            st = self.pool.stats
            st.peak_pages_in_use = st.pages_in_use
            st.admission_denials = 0
            st.offload_calls = 0
            st.onload_calls = 0
            st.peak_host_pages = st.host_pages_in_use
            st.prefix_lookups = 0
            st.prefix_hits = 0
            st.prefix_evictions = 0
            st.peak_slabs_in_use = st.slabs_in_use
            st.peak_cross_in_use = st.cross_in_use
            st.cross_lookups = 0
            st.cross_hits = 0
            st.cross_evictions = 0
            self._prefix_hits = 0
            self._prefill_skipped = 0
            self._cow_copies = 0

    def metrics(self) -> dict:
        """Throughput/latency/occupancy counters for the work so far."""
        lat = [r.t_done - r.t_enqueue for r in self.finished]
        ttft = [r.t_first_token - r.t_enqueue for r in self.finished]
        # bytes follow the layout actually allocated: cache dtype, or the
        # codes+scale quantized layout when rt.kv_quant is set. The
        # decoder pattern holds the serving state, so byte helpers see
        # the decode-side cfg; slab/cross regions bill per sequence /
        # per distinct input rather than per token.
        per_tok = kv_bytes_per_token(self._decode_cfg, self.kv_cache_dtype,
                                     kv_scheme=self.kv_scheme)
        slab_bytes = ssm_state_bytes_per_seq(self._decode_cfg,
                                             self.kv_cache_dtype)
        cross_bytes = cross_kv_bytes_per_seq(self._decode_cfg,
                                             self.kv_cache_dtype)
        if self.kv_layout == "paged":
            st = self.pool.stats
            peak_kv = st.peak_pages_in_use * self.page_size * per_tok
            peak_state = (peak_kv
                          + st.peak_slabs_in_use * slab_bytes
                          + st.peak_cross_in_use * cross_bytes)
            # offloaded pages carry the same per-token layout on host
            page_bytes = self.page_size * per_tok
            paged = {"page_size": self.page_size,
                     "n_pages": self.pool.n_pages,
                     "pages_per_seq": self.pages_per_seq,
                     "peak_kv_pages": st.peak_pages_in_use,
                     "admission_denials":
                         st.admission_denials,
                     "prefill_chunk": self.prefill_chunk,
                     # state-cache regions beyond token KV: SSM slabs
                     # (one per live sequence) and cross entries (one
                     # per live distinct encoder input)
                     "n_slabs": st.n_slabs,
                     "slabs_in_use": st.slabs_in_use,
                     "peak_slabs": st.peak_slabs_in_use,
                     "slab_bytes_per_seq": int(slab_bytes),
                     "n_cross": st.n_cross,
                     "cross_in_use": st.cross_in_use,
                     "peak_cross": st.peak_cross_in_use,
                     "cross_bytes_per_entry": int(cross_bytes),
                     "cross_lookups": st.cross_lookups,
                     "cross_hits": st.cross_hits,
                     "cross_evictions": st.cross_evictions,
                     # continuous-batching scheduler: preempt/resume
                     # traffic and the two-tier memory picture
                     "preemptions": self._preemptions,
                     "resumes": self._resumes,
                     "offload_bytes": self._offload_bytes,
                     "onload_bytes": self._onload_bytes,
                     "host_pages": self.pool.host_pages,
                     "host_pages_in_use": st.host_pages_in_use,
                     "peak_host_pages": st.peak_host_pages,
                     "peak_host_bytes": st.peak_host_pages * page_bytes,
                     # prefix-cache economics (pool-side counters)
                     "prefix_cache_pages": self.pool.cache_pages,
                     "prefix_lookups": st.prefix_lookups,
                     "prefix_evictions": st.prefix_evictions,
                     "prefix_hit_rate":
                         st.prefix_hits / st.prefix_lookups
                         if st.prefix_lookups else 0.0,
                     "prefix_cache": self.prefix_cache,
                     "prefix_hits": self._prefix_hits,
                     "prefill_tokens_skipped": self._prefill_skipped,
                     "cow_copies": self._cow_copies,
                     "spec_decode": bool(self.spec_k),
                     "spec_k": self.spec_k,
                     "fused_decode": self.fused_decode,
                     # drafts accepted per drafted window (one window =
                     # one slot that proposed >= 1 draft this tick) /
                     # per proposed draft token — 0.0 until one ran
                     "accepted_per_step":
                         self._spec_accepted / self._spec_windows
                         if self._spec_windows else 0.0,
                     "draft_acceptance_rate":
                         self._spec_accepted / self._spec_proposed
                         if self._spec_proposed else 0.0}
            if self._has_pages:
                # per-shard budget (planner): how one model shard's slice
                # of the pool actually bills. shards=1 degenerates to the
                # global numbers.
                dcfg = self._decode_cfg
                budget = planner.plan_shard_budget(
                    dcfg.n_kv_heads, dcfg.dh, shards=self.shards,
                    page_size=self.page_size, n_pages=self.pool.n_pages,
                    n_layers=self._paged_layers(),
                    slab_bytes=int(slab_bytes),
                    act_bytes=self.kv_cache_dtype.itemsize,
                    kv_scheme=self.kv_scheme)
                paged.update(
                    kv_sharded=budget.kv_sharded,
                    kv_heads_per_shard=budget.kv_heads_per_shard,
                    pool_bytes_per_shard=budget.pool_bytes,
                    peak_kv_bytes_per_shard=int(
                        st.peak_pages_in_use * budget.page_bytes))
        else:
            # dense bills every slot its worst case up front: max_seq of
            # token KV plus the full recurrent slab and a private cross
            # block per slot, whether or not a request ever lands there
            peak_kv = self.batch_slots * self.max_seq * per_tok
            peak_state = self.batch_slots * (self.max_seq * per_tok
                                             + slab_bytes + cross_bytes)
            paged = {}
        return {
            "kv_layout": self.kv_layout,
            "scheduler": self.scheduler,
            "shards": self.shards,
            "undrained_runs": self._undrained_runs,
            "drained": self.drained,
            "kv_scheme": self.kv_scheme or "none",
            # what the cache arrays actually hold: the quantized layout
            # ignores kv_cache_dtype (codes are uint8, scales f32)
            "kv_cache_dtype": ("uint8+f32scale" if self.kv_scheme
                               else self.kv_cache_dtype.name),
            "requests_finished": len(self.finished),
            "requests_cancelled": self._cancelled,
            "tokens_generated": self._tokens_out,
            "engine_steps": self._steps,
            "model_calls": self._model_calls,
            "wall_s": self._wall,
            "tokens_per_s": self._tokens_out / self._wall
            if self._wall else 0.0,
            "ttft_p50_ms": 1e3 * float(np.median(ttft)) if ttft else 0.0,
            "ttft_p95_ms": 1e3 * float(np.percentile(ttft, 95))
            if ttft else 0.0,
            "latency_p50_ms": 1e3 * float(np.median(lat)) if lat else 0.0,
            "latency_p95_ms": 1e3 * float(np.percentile(lat, 95))
            if lat else 0.0,
            "occupancy_mean": float(np.mean(self._occ_samples))
            if self._occ_samples else 0.0,
            "occupancy_peak": float(np.max(self._occ_samples))
            if self._occ_samples else 0.0,
            "peak_kv_bytes": int(peak_kv),
            # the unified bill: token KV + SSM slabs + cross entries —
            # comparable across layouts and architectures
            "peak_state_bytes": int(peak_state),
            **paged,
        }

    # -- paged internals -----------------------------------------------------

    def _match_prefix(self, req: Request):
        """-> (shared full pages, COW source page or None, matched tokens).

        The pool's index matches page-aligned full pages of the prompt.
        When the *whole* prompt is covered (page-aligned identical
        prompt), the last matched page cannot simply be mapped: the final
        prompt token must be re-run to produce first-token logits, and
        its KV write would land in the shared page. That page becomes a
        copy-on-write source instead — admission copies it into a private
        fresh page and prefill resumes at the last token. Matched tokens
        are therefore always < len(prompt), so every admitted request
        flows through the normal prefill-completion path."""
        keys = self._prompt_keys.get(req.rid)
        if keys is None:
            keys = self._prompt_keys[req.rid] = \
                self.pool.prompt_keys(req.prompt)
        cand = self.pool.match_prefix(req.prompt, keys=keys)
        if not cand:
            return [], None, 0
        matched = len(cand) * self.page_size
        if matched < len(req.prompt):
            return cand, None, matched
        return cand[:-1], cand[-1], len(req.prompt) - 1

    def _admit_paged(self):
        """Admission is page-budget-based either way: a request enters a
        slot only when the pool covers its worst-case token footprint
        (prompt + max_new, capped at max_seq — reserved up front so
        decode can never OOM mid-sequence) minus any shared-prefix pages
        the prefix cache maps in place of fresh ones
        (``planner.plan_seq_pages``). The *policy* differs:

        * ``fifo`` — the original synchronous baseline: strict submit
          order, a blocked head blocks the queue (no starvation of long
          prompts by short ones, no preemption).
        * ``cb`` — continuous batching: candidates are tried in
          (priority desc, submit order) order, the first that fits is
          admitted (skip-ahead keeps slots busy), and when nothing fits
          the top candidate may preempt strictly-lower-priority
          residents — their written KV pages offload to the host tier
          and they resume later from the exact write cursor.
        """
        if self.scheduler == "cb":
            self._admit_cb()
        else:
            self._admit_fifo()

    def _admit_fifo(self):
        for slot in range(self.batch_slots):
            if not self.queue:
                return
            if self.slot_req[slot] is not None:
                continue
            if not self._try_admit(self.queue[0], slot):
                return                      # wait for a release

    def _admit_cb(self):
        while self.queue:
            free = [s for s in range(self.batch_slots)
                    if self.slot_req[s] is None]
            order = sorted(self.queue,
                           key=lambda r: (-r.priority, r._seq))
            admitted = False
            if free:
                for req in order:
                    if self._try_admit(req, free[0]):
                        admitted = True
                        break
            if admitted:
                continue
            # nobody fits as-is: preempt on behalf of the top candidate
            # only (preempting for a skip-ahead candidate could evict
            # work the top one is about to need), then admit it straight
            # away — every loop iteration either admits or returns, so
            # admission can never spin on a preemption that didn't pay
            if not self._preempt_for(order[0]):
                return
            slot = next(s for s in range(self.batch_slots)
                        if self.slot_req[s] is None)
            if not self._try_admit(order[0], slot):
                return

    def _try_admit(self, req: Request, slot: int) -> bool:
        """Try to place ``req`` into the free ``slot``: fresh admission
        (prefix-cache matching included) or resume-from-offload when the
        request carries preemption state. Pops it from the queue and
        returns True on success; False leaves every piece of state — the
        queue, the pool, the slot — untouched."""
        if req._resume is not None:
            return self._try_resume(req, slot)
        shared, cow_src, matched = ([], None, 0)
        if self.prefix_cache:
            shared, cow_src, matched = self._match_prefix(req)
        kv_tokens = (self._worst_case_tokens(req)
                     if self._has_pages else 0)
        pages = self.pool.allocate(req.rid, kv_tokens,
                                   shared_prefix=shared,
                                   need_slab=self._has_slab,
                                   cross_key=self._frames_key(req))
        if pages is None:                    # NOT truthiness: a pageless
            return False                     # success returns []
        if cow_src is not None:
            # private copy of the partially-reused last page; the
            # re-run final token overwrites its own (identical) KV
            self.caches = self._copy_page(
                self.caches, jnp.int32(cow_src),
                jnp.int32(pages[len(shared)]))
            self._cow_copies += 1
        if matched:
            self._prefix_hits += 1
            self._prefill_skipped += matched
        if self._has_slab:
            # a fresh sequence starts from zero recurrent state; the
            # slab index is recycled, so the zero is explicit
            self.caches = self._reset_slabs(
                self.caches, jnp.int32(self.pool.seq_slab(req.rid)))
        if self._has_cross and self.pool.consume_cross_fresh(req.rid):
            # cross-region miss: run the encoder + per-slot K/V
            # projection once and fill the claimed entry. A hit (same
            # frames as a live or cached entry) skips this entirely —
            # the whole encoder pass is reused.
            entries = self._encode_cross(
                self.params,
                jnp.asarray(req.frames, jnp.float32)[None],
                self.cfg, self.rt)
            self._model_calls += 1
            self.caches = self._fill_cross(
                self.caches, jnp.int32(self.pool.seq_cross(req.rid)),
                entries)
        self.queue.remove(req)
        self.slot_req[slot] = req
        self.slot_pos[slot] = matched
        self._fed[slot] = matched
        self._set_block_row(slot, req.rid)
        self._sync_state_idx(slot, req.rid)
        if self.spec_k:
            # the drafter indexes the FULL prompt (matched prefix
            # included) — sharing changes where KV bytes live, not
            # what n-grams the sequence's history contains
            self.drafter.start(req.rid, req.prompt)
        return True

    # -- preemption + resume (cb scheduler; docs/SERVING.md lifecycle) -------

    def _snapshot_pages(self, pages: tuple[int, ...]):
        """Gather the written pages' bytes to host (the offload payload).
        Indices pad to a power of two by repeating the last page so
        O(log) compiles cover every preemption; the duplicates are
        sliced off after the device_get."""
        n = len(pages)
        n_pad = _pad_pow2(n, self.pages_per_seq)
        idx = np.full(n_pad, pages[-1], np.int32)
        idx[:n] = pages
        snap = jax.device_get(self._gather_pages(self.caches,
                                                 jnp.asarray(idx)))
        return jax.tree_util.tree_map(lambda leaf: leaf[:, :n], snap)

    def _restore_pages(self, pages: list[int], payload):
        """Scatter an offload payload into freshly allocated pages (the
        first ``n`` of the new reservation, in logical order). Padding
        duplicates the last (index, payload row) pair, so duplicate
        scatter writes carry identical bytes — deterministic."""
        n = jax.tree_util.tree_leaves(payload)[0].shape[1]
        n_pad = _pad_pow2(n, self.pages_per_seq)
        idx = np.full(n_pad, pages[n - 1], np.int32)
        idx[:n] = pages[:n]
        if n_pad > n:
            payload = jax.tree_util.tree_map(
                lambda leaf: np.concatenate(
                    [leaf, np.repeat(leaf[:, -1:], n_pad - n, axis=1)],
                    axis=1),
                payload)
        self.caches = self._scatter_pages(self.caches, jnp.asarray(idx),
                                          payload)

    def _preempt_slot(self, slot: int) -> bool:
        """Evict the resident request: snapshot the pages covering its
        write cursor, park them (and the bytes) on the pool's host tier,
        release its device pages ref-aware, and requeue it carrying
        resume state. Returns False (state untouched) when the host tier
        cannot take the pages. Everything past the write cursor —
        unwritten reservation, rejected speculative tails — is garbage
        that was never attended, so it is deliberately not snapshotted."""
        req = self.slot_req[slot]
        n_written = int(self.slot_pos[slot])
        fed = int(self._fed[slot])
        if self._has_pages:
            _, n_keep = planner.plan_resume_pages(
                n_written, self._worst_case_tokens(req), self.page_size)
        else:
            n_keep = 0
        page_payload = (
            self._snapshot_pages(self.pool.seq_pages(req.rid)[:n_keep])
            if n_keep else None)
        slab_payload = None
        if self._has_slab:
            # the slab is the sequence's entire recurrent state — O(1)
            # in written tokens, always snapshotted whole
            slab_payload = jax.device_get(self._gather_slabs(
                self.caches, jnp.int32(self.pool.seq_slab(req.rid))))
        payload = ((page_payload, slab_payload)
                   if page_payload is not None or slab_payload is not None
                   else None)
        if self.pool.offload(req.rid, n_keep, payload) is None:
            return False                    # host tier full
        for part in (page_payload, slab_payload):
            if part is not None:
                self._offload_bytes += sum(
                    leaf.nbytes
                    for leaf in jax.tree_util.tree_leaves(part))
        req._resume = (n_written, fed)
        req.preemptions += 1
        self._preemptions += 1
        self.slot_req[slot] = None
        self.block_tables[slot] = 0
        self.slot_pos[slot] = 0
        self._fed[slot] = -1
        self._state_idx[slot] = (self._n_slabs, self._n_cross)
        if self.spec_k:
            # the n-gram index rebuilds deterministically from
            # prompt + output at resume — nothing to keep
            self.drafter.drop(req.rid)
        self.queue.append(req)
        return True

    def _try_resume(self, req: Request, slot: int) -> bool:
        """Bring a preempted request back: fresh worst-case reservation
        (no prefix sharing — the restored bytes are private), scatter the
        host snapshot into the new pages, and re-enter the tick loop at
        the exact (write cursor, prefill progress) it was evicted at."""
        n_written, fed = req._resume
        kv_tokens = (self._worst_case_tokens(req)
                     if self._has_pages else 0)
        res = self.pool.onload(req.rid, kv_tokens)
        if res is None:
            return False                    # device capacity still short
        pages, payload = res
        page_payload, slab_payload = (payload if payload is not None
                                      else (None, None))
        if page_payload is not None:
            self._restore_pages(pages, page_payload)
            self._onload_bytes += sum(
                leaf.nbytes
                for leaf in jax.tree_util.tree_leaves(page_payload))
        if slab_payload is not None:
            # the reacquired slab index may differ from the one held at
            # offload — scatter wherever the pool now points
            self.caches = self._scatter_slabs(
                self.caches, jnp.int32(self.pool.seq_slab(req.rid)),
                slab_payload)
            self._onload_bytes += sum(
                leaf.nbytes
                for leaf in jax.tree_util.tree_leaves(slab_payload))
        self.queue.remove(req)
        req._resume = None
        self._resumes += 1
        self.slot_req[slot] = req
        self.slot_pos[slot] = n_written
        self._fed[slot] = fed
        self._set_block_row(slot, req.rid)
        self._sync_state_idx(slot, req.rid)
        if self.spec_k:
            # deterministic rebuild: the incremental index over
            # prompt + emitted output is a pure function of both
            self.drafter.start(req.rid, req.prompt)
            for tok in req.output:
                self.drafter.extend(req.rid, int(tok))
        return True

    def _preempt_for(self, cand: Request) -> bool:
        """Preempt strictly-lower-priority residents until ``cand`` has a
        slot and enough free pages, lowest priority first (youngest
        breaking ties — they lose the least progress). Prechecked against
        both tiers before any eviction: the chosen victims' releasable
        pages (shared pages with other owners free nothing) must cover
        the candidate's worst-case need, and the host tier must have room
        for every victim's written pages — a half-done preemption wave
        would evict work without admitting anyone. Equal priorities never
        preempt: that is what keeps cb admission FIFO-compatible (and
        livelock-free — the highest-priority resident always runs)."""
        need = (planner.plan_seq_pages(self._worst_case_tokens(cand),
                                       self.page_size)
                if self._has_pages else 0)
        victims = sorted(
            (s for s, r in enumerate(self.slot_req)
             if r is not None and r.priority < cand.priority),
            key=lambda s: (self.slot_req[s].priority,
                           -self.slot_req[s]._seq))
        free_slot = any(r is None for r in self.slot_req)
        gain = self.pool.free_pages()
        host_extra = 0
        chosen: list[int] = []
        for s in victims:
            if gain >= need and (free_slot or chosen):
                break
            if self._has_pages:
                _, n_keep = planner.plan_resume_pages(
                    int(self.slot_pos[s]),
                    self._worst_case_tokens(self.slot_req[s]),
                    self.page_size)
            else:
                n_keep = 0
            if (self.pool.host_pages is not None
                    and self.pool.stats.host_pages_in_use + host_extra
                    + n_keep > self.pool.host_pages):
                continue                    # host tier can't take this one
            chosen.append(s)
            gain += self.pool.releasable_pages(self.slot_req[s].rid)
            host_extra += n_keep
        if gain < need or not (free_slot or chosen):
            return False
        preempted = False
        for s in chosen:
            preempted |= self._preempt_slot(s)
        return preempted

    def preempt(self, rid: int):
        """Force-preempt a resident request (fault injection / tests —
        the cb scheduler calls ``_preempt_for`` itself). Raises KeyError
        when ``rid`` is not resident, RuntimeError when the host tier
        cannot take its pages."""
        if self.kv_layout != "paged":
            raise ValueError("preempt() needs kv_layout='paged'")
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                if not self._preempt_slot(slot):
                    raise RuntimeError(
                        f"request {rid}: host tier full "
                        f"({self.pool.stats.host_pages_in_use}/"
                        f"{self.pool.host_pages} pages)")
                return
        raise KeyError(f"request {rid} is not resident in any slot")

    def _prefill_tick(self):
        """Advance every prefilling slot by one prompt chunk in a single
        batched call (per-row ctx_len/n_valid make ragged rows legal —
        same mechanism the decode step uses; non-prefilling rows ride
        along masked with n_valid=0). Interleaved with the batch decode
        step so running sequences keep producing tokens."""
        rows = [i for i in range(self.batch_slots)
                if self.slot_req[i] is not None and self._fed[i] >= 0]
        if not rows:
            return
        chunk = {i: min(self.prefill_chunk,
                        len(self.slot_req[i].prompt) - int(self._fed[i]))
                 for i in rows}
        c_pad = _pad_pow2(max(chunk.values()), self.prefill_chunk)
        tokens = np.zeros((self.batch_slots, c_pad), np.int32)
        ctx = np.zeros(self.batch_slots, np.int32)
        n_valid = np.zeros(self.batch_slots, np.int32)
        for i in rows:
            fed, c = int(self._fed[i]), chunk[i]
            tokens[i, :c] = self.slot_req[i].prompt[fed:fed + c]
            ctx[i] = fed
            n_valid[i] = c
        logits, self.caches = self._paged_step(
            self.params, jnp.asarray(tokens), jnp.asarray(ctx),
            jnp.asarray(self.block_tables), jnp.asarray(n_valid),
            jnp.asarray(self._state_idx), self.caches, self.cfg, self.rt)
        self._model_calls += 1
        logits = np.asarray(logits)
        for i in rows:
            req = self.slot_req[i]
            self._fed[i] += chunk[i]
            self.slot_pos[i] = self._fed[i]
            if self.prefix_cache:
                # index every prompt page this chunk completed — full
                # prompt pages are immutable from here on, so queued
                # requests with the same prefix can start sharing them
                # on the very next admission tick (before _maybe_finish:
                # a released page stays indexed and revivable)
                self.pool.register_prefix(
                    req.rid, req.prompt, int(self._fed[i]),
                    keys=self._prompt_keys.get(req.rid))
            if self._fed[i] == len(req.prompt):
                self._fed[i] = -1           # -> decoding
                first = self._pick_token(logits[i], req)
                req.output.append(int(first))
                self._tokens_out += 1
                if self.spec_k:
                    self.drafter.extend(req.rid, int(first))
                req.t_first_token = _now()
                self._maybe_finish(i)       # max_new_tokens == 1

    def _decode_step_paged(self):
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and self._fed[i] < 0]
        if not active:
            return
        if self.fused_decode:
            self._decode_step_fused(active)
            return
        if self.spec_k:
            drafts = {}
            for i in active:
                req = self.slot_req[i]
                room = self._draft_room(req, int(self.slot_pos[i]))
                drafts[i] = (self.drafter.propose(req.rid,
                                                  min(self.spec_k, room))
                             if room > 0 else [])
            if any(drafts.values()):
                self._verify_step(active, drafts)
                return
            # every tail was novel: degrade to the plain one-token step
            # below (the C==1 decode kernel) instead of paying the K+1
            # verify window for zero drafts
        tokens = np.zeros((self.batch_slots, 1), np.int32)
        n_valid = np.zeros(self.batch_slots, np.int32)
        ctx = np.zeros(self.batch_slots, np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].output[-1]
            n_valid[i] = 1
            ctx[i] = self.slot_pos[i]
        logits, self.caches = self._paged_step(
            self.params, jnp.asarray(tokens), jnp.asarray(ctx),
            jnp.asarray(self.block_tables), jnp.asarray(n_valid),
            jnp.asarray(self._state_idx), self.caches, self.cfg, self.rt)
        self._model_calls += 1
        logits = np.asarray(logits)
        for i in active:
            req = self.slot_req[i]
            tok = self._pick_token(logits[i], req)
            req.output.append(int(tok))
            if self.spec_k:
                # keep the n-gram history == prompt + output even on
                # degraded (no-draft) ticks, or later proposals would
                # continue from a stale tail
                self.drafter.extend(req.rid, int(tok))
            self._tokens_out += 1
            self.slot_pos[i] += 1
            self._maybe_finish(i)

    def _decode_step_fused(self, active):
        """One megakernel decode tick for every decoding slot: plain
        decode and draft-verify collapse onto a single
        ``lm_paged_fused_step`` call over a fixed window W (spec_k + 1,
        or 1 without speculation) — per-row ``n_valid`` carries the
        ragged part (1 + drafts for this slot), so drafted and undrafted
        rows share the launch instead of forking into separate
        ``_paged_step`` / ``_paged_verify`` compiles. Acceptance,
        rollback and drafter bookkeeping are identical to the unfused
        path (``_accept_tokens`` with an empty draft list IS the plain
        greedy/sampled pick, same key chain), so greedy outputs are
        bit-identical fused vs unfused — regression-tested."""
        w = (self.spec_k + 1) if self.spec_k else 1
        drafts: dict[int, list[int]] = {i: [] for i in active}
        if self.spec_k:
            for i in active:
                req = self.slot_req[i]
                room = self._draft_room(req, int(self.slot_pos[i]))
                if room > 0:
                    drafts[i] = self.drafter.propose(req.rid,
                                                     min(self.spec_k, room))
        tokens = np.zeros((self.batch_slots, w), np.int32)
        n_valid = np.zeros(self.batch_slots, np.int32)
        ctx = np.zeros(self.batch_slots, np.int32)
        for i in active:
            req = self.slot_req[i]
            d = drafts[i]
            tokens[i, 0] = req.output[-1]
            if d:
                tokens[i, 1:1 + len(d)] = d
            n_valid[i] = 1 + len(d)
            ctx[i] = self.slot_pos[i]
        logits, self.caches = self._fused_step(
            self.params, jnp.asarray(tokens), jnp.asarray(ctx),
            jnp.asarray(self.block_tables), jnp.asarray(n_valid),
            jnp.asarray(self._state_idx), self.caches, self.cfg, self.rt)
        self._model_calls += 1
        logits = np.asarray(logits)                  # (B, W, V)
        for i in active:
            req = self.slot_req[i]
            emitted = self._accept_tokens(req, drafts[i], logits[i])
            accepted = len(emitted) - 1              # drafts kept
            for tok in emitted:
                req.output.append(int(tok))
                if self.spec_k:
                    self.drafter.extend(req.rid, int(tok))
            self._tokens_out += len(emitted)
            if drafts[i]:
                self._spec_windows += 1
            self._spec_proposed += len(drafts[i])
            self._spec_accepted += accepted
            # KV rollback: pending token + accepted drafts stay; the
            # write cursor retreats past any rejected tail
            self.slot_pos[i] = int(ctx[i]) + 1 + accepted
            self._maybe_finish(i)

    # -- speculative decoding (serving/spec.py has the drafter) --------------

    def _draft_room(self, req: Request, pos: int) -> int:
        """Max draft tokens this window may carry. Two caps: the window
        emits up to d+1 tokens (never past max_new_tokens) and writes
        positions pos..pos+d (never past the positions a non-speculative
        decode could reach, so the worst-case page reservation still
        covers every write)."""
        return min(req.max_new_tokens - len(req.output),
                   self.max_seq - 1 - pos) - 1

    def _verify_step(self, active, drafts: dict[int, list[int]]):
        """Draft-and-verify decode tick: at least one decoding slot has
        ``drafts`` (rows with none ride along as 1-valid plain decodes),
        one batched ``lm_paged_verify`` scores all windows, and each row
        keeps its longest accepted prefix plus a bonus or correction
        token. Rollback is cursor arithmetic: ``slot_pos`` advances only
        past accepted tokens; the rejected tail's page slots are
        overwritten by the next window at those positions and are never
        attended (``attend_len`` masks them)."""
        w = self.spec_k + 1
        tokens = np.zeros((self.batch_slots, w), np.int32)
        n_valid = np.zeros(self.batch_slots, np.int32)
        ctx = np.zeros(self.batch_slots, np.int32)
        for i in active:
            req = self.slot_req[i]
            d = drafts[i]
            tokens[i, 0] = req.output[-1]
            if d:
                tokens[i, 1:1 + len(d)] = d
            n_valid[i] = 1 + len(d)
            ctx[i] = self.slot_pos[i]
        logits, self.caches = self._paged_verify(
            self.params, jnp.asarray(tokens), jnp.asarray(ctx),
            jnp.asarray(self.block_tables), jnp.asarray(n_valid),
            jnp.asarray(self._state_idx), self.caches, self.cfg, self.rt)
        self._model_calls += 1
        logits = np.asarray(logits)                  # (B, W, V)
        for i in active:
            req = self.slot_req[i]
            emitted = self._accept_tokens(req, drafts[i], logits[i])
            accepted = len(emitted) - 1              # drafts kept
            for tok in emitted:
                req.output.append(int(tok))
                self.drafter.extend(req.rid, int(tok))
            self._tokens_out += len(emitted)
            if drafts[i]:
                self._spec_windows += 1
            self._spec_proposed += len(drafts[i])
            self._spec_accepted += accepted
            # KV rollback: pending token + accepted drafts stay; the
            # write cursor retreats past the rejected tail
            self.slot_pos[i] = int(ctx[i]) + 1 + accepted
            self._maybe_finish(i)

    def _accept_tokens(self, req: Request, drafts: list[int],
                       logits: np.ndarray) -> list[int]:
        """Tokens to emit for one verified window. ``logits``: (W, V),
        position j scored after window token j. Greedy: longest prefix of
        drafts matching argmax, then the correction (first mismatch) or
        bonus (all matched) token — by construction exactly the sequence
        non-speculative greedy decode would emit. Temperature: per-draft
        rejection sampling against the target distribution; the drafter
        is deterministic (a point mass), so acceptance of draft t is a
        Bernoulli(p[t]) draw and a rejection resamples from the residual
        p with t removed — the emitted token is still distributed per
        the target model. All draws come from the request's own key
        chain."""
        if req.temperature <= 0:
            out = []
            for j, t in enumerate(drafts):
                top = int(np.argmax(logits[j]))
                if top != t:
                    return out + [top]               # correction
                out.append(t)
            out.append(int(np.argmax(logits[len(drafts)])))  # bonus
            return out
        out = []
        for j, t in enumerate(drafts):
            p = _softmax_np(logits[j], req.temperature)
            req.key, sub = jax.random.split(req.key)
            if float(jax.random.uniform(sub)) < p[t]:
                out.append(t)
                continue
            # rejected: resample from the residual (p minus the point
            # mass at t, renormalized)
            res = p.copy()
            res[t] = 0.0
            z = res.sum()
            req.key, sub = jax.random.split(req.key)
            if z <= 0.0:                             # p was ~all at t
                return out + [int(np.argmax(logits[j]))]
            return out + [int(jax.random.choice(sub, res.shape[0],
                                                p=jnp.asarray(res / z)))]
        out.append(self._pick_token(logits[len(drafts)], req))  # bonus
        return out

    def _maybe_finish(self, slot: int):
        req = self.slot_req[slot]
        if (len(req.output) >= req.max_new_tokens
                or self.slot_pos[slot] >= self.max_seq - 1):
            self._finish(slot)

    def _finish(self, slot: int):
        req = self.slot_req[slot]
        req.done = True
        req.t_done = _now()
        self.finished.append(req)
        st = self._streams.get(req.rid)
        if st is not None:
            st.finish()
        self.slot_req[slot] = None
        if self.kv_layout == "paged":
            # release recycles zero-ref pages, returns the slab to the
            # free list and drops the cross reference (a zero-ref cross
            # entry stays indexed — cached-free, revivable by key)
            self.pool.release(req.rid)
            self.block_tables[slot] = 0
            self._fed[slot] = -1
            self._state_idx[slot] = (self._n_slabs, self._n_cross)
            self._prompt_keys.pop(req.rid, None)
            self._frames_keys.pop(req.rid, None)
            if self.spec_k:
                self.drafter.drop(req.rid)

    # -- dense internals -----------------------------------------------------

    def _admit_dense(self):
        for slot in range(self.batch_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[slot] = req
                # prefill this slot: run prompt through a single-row batch,
                # then splice its caches into the engine batch at `slot`
                tok = jnp.asarray(req.prompt, jnp.int32)[None, :]
                if self.cfg.enc_dec:
                    row_caches = encdec_mod.encdec_init_caches(
                        self.cfg, 1, self.max_seq,
                        dtype=self.kv_cache_dtype,
                        kv_quant=self.rt.kv_quant)
                    frames = jnp.asarray(req.frames, jnp.float32)[None]
                    logits, row_caches = self._prefill_one(
                        self.params, frames, tok, row_caches, self.cfg,
                        self.rt)
                else:
                    row_caches = lm_mod.init_caches(
                        self.cfg, 1, self.max_seq,
                        dtype=self.kv_cache_dtype,
                        kv_quant=self.rt.kv_quant)
                    logits, row_caches = self._prefill_one(
                        self.params, tok, row_caches, self.cfg, self.rt)
                self._model_calls += 1
                self.caches = _splice_caches(self.caches, row_caches, slot)
                self.slot_pos[slot] = len(req.prompt)
                first = self._pick_token(logits[0], req)
                req.output.append(int(first))
                self._tokens_out += 1
                req.t_first_token = _now()
                self._maybe_finish(slot)    # max_new_tokens == 1

    def _decode_step_dense(self):
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tokens = np.zeros(self.batch_slots, np.int32)
        for i in active:
            tokens[i] = self.slot_req[i].output[-1]
        # continuous batching: each slot decodes at its own position
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.caches = self._decode(self.params,
                                           jnp.asarray(tokens),
                                           pos, self.caches, self.cfg,
                                           self.rt)
        self._model_calls += 1
        logits = np.asarray(logits)
        for i in active:
            req = self.slot_req[i]
            tok = self._pick_token(logits[i], req)
            req.output.append(int(tok))
            self._tokens_out += 1
            self.slot_pos[i] += 1
            self._maybe_finish(i)

    # -- shared --------------------------------------------------------------

    def _pick_token(self, row: np.ndarray, req: Request) -> int:
        if req.temperature <= 0:
            return int(np.argmax(row))
        # per-request chain: the draw sequence is a function of this
        # request alone, so sampled outputs are invariant to submit
        # order, slot assignment and batch-mates (regression-tested)
        req.key, sub = jax.random.split(req.key)
        return int(jax.random.categorical(sub, jnp.asarray(row)
                                          / req.temperature))


def _softmax_np(row: np.ndarray, temperature: float) -> np.ndarray:
    """Stable softmax over a logits row (f64 — host-side acceptance
    probabilities for speculative rejection sampling)."""
    x = np.asarray(row, np.float64) / temperature
    x = x - x.max()
    p = np.exp(x)
    return p / p.sum()


def _splice_caches(batch_caches, row_caches, slot: int):
    """Insert a prefilled single-row cache at batch index ``slot``. Cache
    leaves have layout (P, B, ...)."""
    def splice(bc, rc):
        return bc.at[:, slot:slot + 1].set(rc)
    return jax.tree_util.tree_map(splice, batch_caches, row_caches)
