"""Paged KV cache: block-table allocation over a fixed pool of KV pages.

The dense engine's memory bill is ``batch_slots x max_seq`` cache rows no
matter how short the actual sequences are — the padded-waste problem
RedMulE/FantastIC4 attack with adaptive sizing. Here the cache is a fixed
pool of fixed-size *pages* (the device arrays live in the model's cache
pytree, shaped ``(n_pages, Hkv, page_size, dh)`` per layer); this module is
the **host-side** allocator that maps sequences onto pages:

  * each sequence owns an ordered list of physical page indices; logical
    token position ``p`` lives at ``(pages[p // page_size], p % page_size)``
  * a free list recycles pages the moment a sequence finishes (LIFO, so
    recently-touched pages are reused first)
  * admission asks ``can_admit(n_tokens)`` — a request whose worst-case
    footprint exceeds the currently free pages stays queued instead of
    crashing or evicting others

The *device* side consumes only the ``block_table`` this produces: an
``(n_seqs, pages_per_seq)`` int32 array of physical page indices that the
paged-attention kernel uses to gather K/V (see kernels/paged_attention.py).
Unused table slots point at page 0 and are masked by the context length.

Sizing (all byte helpers return bytes; counts are tokens/pages):
``page_bytes_per_token`` x ``page_size`` x ``n_pages`` is the whole pool —
see docs/SERVING.md for a worked example.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PagePool", "kv_bytes_per_token", "pool_bytes", "PoolStats"]


def kv_bytes_per_token(cfg, dtype_bytes: int = 4) -> int:
    """Bytes of K+V cache one token occupies across every attention layer.

    ``cfg``: an ArchConfig; ``dtype_bytes``: cache element width in bytes
    (4 for the f32 serving cache, 2 for bf16). Counts attention mixers only
    — SSM slots carry O(1) state, not per-token KV.
    """
    n_attn = sum(1 for s in cfg.pattern
                 if s.split("+")[0] in ("attn", "xdec"))
    return 2 * cfg.n_periods * n_attn * cfg.n_kv_heads * cfg.dh * dtype_bytes


def pool_bytes(cfg, n_pages: int, page_size: int,
               dtype_bytes: int = 4) -> int:
    """Total device bytes of the paged K/V pool (all layers)."""
    return n_pages * page_size * kv_bytes_per_token(cfg, dtype_bytes)


@dataclasses.dataclass
class PoolStats:
    """Allocator counters. Pages are counted in pages, not bytes."""
    n_pages: int
    page_size: int
    pages_in_use: int = 0
    peak_pages_in_use: int = 0
    alloc_calls: int = 0
    release_calls: int = 0
    admission_denials: int = 0      # distinct sequences denied, not ticks

    @property
    def occupancy(self) -> float:
        return self.pages_in_use / self.n_pages

    @property
    def peak_occupancy(self) -> float:
        return self.peak_pages_in_use / self.n_pages


class PagePool:
    """Host-side page allocator: free list + per-sequence page lists.

    Deterministic (LIFO free list), single-threaded — the engine drives it
    from its scheduling loop. All methods are O(pages touched).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError((n_pages, page_size))
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._seq_pages: dict[int, list[int]] = {}
        self._denied: set[int] = set()
        self.stats = PoolStats(n_pages, page_size)

    # -- queries -------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` tokens (ceil)."""
        return -(-n_tokens // self.page_size)

    def free_pages(self) -> int:
        return len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        """Would ``allocate`` succeed for a new ``n_tokens``-token
        reservation right now?"""
        return self.pages_for(n_tokens) <= len(self._free)

    def seq_page_count(self, seq_id: int) -> int:
        return len(self._seq_pages.get(seq_id, ()))

    # -- mutation ------------------------------------------------------------

    def allocate(self, seq_id: int, n_tokens: int) -> list[int] | None:
        """Reserve pages for ``n_tokens`` tokens of sequence ``seq_id``
        (worst case up front — no mid-decode OOM, no preemption). Returns
        the physical page list, or None when the pool can't cover it; the
        caller keeps the request queued. A denial is counted once per
        sequence, not once per retry — the engine re-asks every tick."""
        if seq_id in self._seq_pages:
            raise KeyError(f"seq {seq_id} already allocated")
        need = self.pages_for(n_tokens)
        self.stats.alloc_calls += 1
        if need > len(self._free):
            if seq_id not in self._denied:
                self._denied.add(seq_id)
                self.stats.admission_denials += 1
            return None
        self._denied.discard(seq_id)
        pages = [self._free.pop() for _ in range(need)]
        self._seq_pages[seq_id] = pages
        self.stats.pages_in_use += need
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.stats.pages_in_use)
        return pages

    def release(self, seq_id: int) -> int:
        """Return a finished sequence's pages to the free list. Returns the
        number of pages reclaimed."""
        pages = self._seq_pages.pop(seq_id)
        self._free.extend(reversed(pages))
        self.stats.pages_in_use -= len(pages)
        self.stats.release_calls += 1
        return len(pages)

    def block_table_row(self, seq_id: int, width: int) -> np.ndarray:
        """(width,) int32 physical-page row for the device block table.
        Slots past the sequence's allocation point at page 0 — the kernel
        masks them via the context length, never reads them as data."""
        pages = self._seq_pages.get(seq_id, [])
        if len(pages) > width:
            raise ValueError(f"seq {seq_id}: {len(pages)} pages > table "
                             f"width {width}")
        row = np.zeros(width, np.int32)
        row[:len(pages)] = pages
        return row
