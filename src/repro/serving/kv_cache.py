"""Paged KV cache: block-table allocation over a fixed pool of KV pages.

The dense engine's memory bill is ``batch_slots x max_seq`` cache rows no
matter how short the actual sequences are — the padded-waste problem
RedMulE/FantastIC4 attack with adaptive sizing. Here the cache is a fixed
pool of fixed-size *pages* (the device arrays live in the model's cache
pytree, shaped ``(n_pages, Hkv, page_size, dh)`` per layer); this module is
the **host-side** allocator that maps sequences onto pages:

  * each sequence owns an ordered list of physical page indices; logical
    token position ``p`` lives at ``(pages[p // page_size], p % page_size)``
  * pages are **reference counted**: a full page holding a page-aligned
    block of prompt tokens is immutable once written, so later requests
    with the same prompt prefix map the *same* physical page instead of
    re-prefilling it (``match_prefix``/``register_prefix`` keep a prefix
    index of chain hashes over page-aligned token blocks); a page recycles
    only when its refcount hits zero
  * a free list recycles pages the moment their last owner finishes (LIFO,
    so recently-touched pages are reused first). A freed page *stays in
    the prefix index* until the free list hands it out again (lazy
    eviction) — a system prompt survives in the pool between request
    waves for free
  * admission asks ``can_admit(n_tokens)`` — a request whose worst-case
    footprint exceeds the currently free pages stays queued instead of
    crashing or evicting others

The *device* side consumes only the ``block_table`` this produces: an
``(n_seqs, pages_per_seq)`` int32 array of physical page indices that the
paged-attention kernel uses to gather K/V (see kernels/paged_attention.py).
Unused table slots point at page 0 and are masked by the context length.
Shared pages appear in several rows at once — the device neither knows nor
cares; ownership and copy-on-write live here and in the engine.

Sizing (all byte helpers return bytes; counts are tokens/pages):
``kv_bytes_per_token`` x ``page_size`` x ``n_pages`` is the whole pool —
derived from the *actual* cache dtype (and the codes+scale layout when the
pool is quantized); see docs/SERVING.md for a worked example.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import spx
from repro.runtime import planner

__all__ = ["PagePool", "kv_bytes_per_token", "pool_bytes", "PoolStats"]


def _elem_bytes(cache_dtype) -> int:
    """Element width in bytes from a dtype (or a raw int, kept for the old
    ``dtype_bytes`` call style)."""
    if isinstance(cache_dtype, int):
        return cache_dtype
    return int(np.dtype(cache_dtype).itemsize)


def kv_bytes_per_token(cfg, cache_dtype=4, *,
                       kv_scheme: str | None = None) -> int:
    """Bytes of K+V cache one token occupies across every attention layer.

    ``cfg``: an ArchConfig; ``cache_dtype``: the dtype the cache arrays are
    actually allocated with (e.g. ``jnp.float32``/``jnp.bfloat16`` — pass
    whatever went to ``init_caches``/``paged_init_caches``; a raw byte
    count is accepted for back-compat). ``kv_scheme`` set (any core/spx
    scheme name) switches to the quantized codes+scale layout: 1 byte of
    uint8 code per element plus a 4-byte f32 scale per (token, KV head)
    side — ``cache_dtype`` is then ignored, matching the allocation.
    Counts attention mixers only — SSM slots carry O(1) state, not
    per-token KV.
    """
    n_attn = sum(1 for s in cfg.pattern
                 if s.split("+")[0] in ("attn", "xdec"))
    if kv_scheme is not None:
        per_head = spx.kv_token_side_bytes(cfg.dh)   # codes + f32 scale
    else:
        per_head = cfg.dh * _elem_bytes(cache_dtype)
    return 2 * cfg.n_periods * n_attn * cfg.n_kv_heads * per_head


def pool_bytes(cfg, n_pages: int, page_size: int, cache_dtype=4, *,
               kv_scheme: str | None = None) -> int:
    """Total device bytes of the paged K/V pool (all layers) — equal by
    construction to the summed ``.nbytes`` of the arrays
    ``models.lm.paged_init_caches`` allocates for the same geometry
    (regression-tested)."""
    return n_pages * page_size * kv_bytes_per_token(cfg, cache_dtype,
                                                    kv_scheme=kv_scheme)


@dataclasses.dataclass
class PoolStats:
    """Allocator counters. Pages are counted in pages, not bytes;
    ``pages_in_use`` counts *distinct physical* pages (a page shared by
    three sequences counts once — that is the whole point of sharing)."""
    n_pages: int
    page_size: int
    pages_in_use: int = 0
    peak_pages_in_use: int = 0
    alloc_calls: int = 0
    release_calls: int = 0
    admission_denials: int = 0      # distinct sequences denied, not ticks
    prefix_pages_shared: int = 0    # cumulative refcount bumps from sharing

    @property
    def occupancy(self) -> float:
        return self.pages_in_use / self.n_pages

    @property
    def peak_occupancy(self) -> float:
        return self.peak_pages_in_use / self.n_pages


class PagePool:
    """Host-side page allocator: free list + refcounts + per-sequence page
    lists + prefix index.

    Deterministic (LIFO free list), single-threaded — the engine drives it
    from its scheduling loop. All methods are O(pages touched), except the
    O(pool) free-list removal when a cached free page is revived and the
    O(prefix tokens) hashing in ``match_prefix``/``register_prefix``.

    Mutations are transactional: every failure path — a capacity denial
    (returns None) or a caller error (raises) — leaves the free list,
    refcounts, sequence maps, prefix index and stats exactly as they were
    before the call. Validation runs before the first pop, so a partial
    allocation can never leak pages (regression-tested).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError((n_pages, page_size))
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._ref: list[int] = [0] * n_pages
        self._seq_pages: dict[int, list[int]] = {}
        # prefix index: chain hash of a page-aligned token prefix -> the
        # physical page holding its last block. _page_key is the inverse
        # (a page carries at most one index entry) so eviction is O(1).
        self._index: dict[bytes, int] = {}
        self._page_key: dict[int, bytes] = {}
        self._denied: set[int] = set()
        self.stats = PoolStats(n_pages, page_size)

    # -- queries -------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` tokens (ceil) with no shared
        prefix — the planner owns the page-count model."""
        return planner.plan_seq_pages(n_tokens, self.page_size)

    def free_pages(self) -> int:
        return len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        """Would ``allocate`` succeed for a new ``n_tokens``-token
        reservation right now (no shared prefix)?"""
        return self.pages_for(n_tokens) <= len(self._free)

    def seq_page_count(self, seq_id: int) -> int:
        return len(self._seq_pages.get(seq_id, ()))

    def seq_pages(self, seq_id: int) -> tuple[int, ...]:
        """The sequence's physical page list (copy; () when not live)."""
        return tuple(self._seq_pages.get(seq_id, ()))

    def ref_count(self, page: int) -> int:
        """Live owners of a physical page (0 = free or cached-free)."""
        return self._ref[page]

    def cached_prefix_pages(self) -> int:
        """Pages currently carrying a prefix-index entry (live + cached)."""
        return len(self._index)

    # -- prefix index --------------------------------------------------------

    def _page_keys(self, tokens, n_full: int) -> list[bytes]:
        """Chain keys for the first ``n_full`` page-aligned blocks of
        ``tokens``: key k hashes blocks 0..k, so equal keys mean equal
        *prefixes*, not just equal blocks (positional KV — RoPE — makes a
        block's cache content depend on everything before it)."""
        t = np.ascontiguousarray(np.asarray(tokens, dtype=np.int32))
        h = hashlib.sha1()
        keys = []
        for k in range(n_full):
            h.update(t[k * self.page_size:(k + 1) * self.page_size]
                     .tobytes())
            keys.append(h.digest())
        return keys

    def prompt_keys(self, tokens) -> list[bytes]:
        """Chain keys for every full page of ``tokens``. Hashing is O(len)
        — compute once per prompt and hand the result to ``match_prefix``
        / ``register_prefix`` so a blocked queue head retried every tick
        (or a prompt registered chunk by chunk) doesn't re-hash from
        block 0 each time."""
        return self._page_keys(tokens, len(tokens) // self.page_size)

    def match_prefix(self, tokens, *, keys=None) -> list[int]:
        """Physical pages holding the longest indexed page-aligned prefix
        of ``tokens`` (possibly all ``len(tokens) // page_size`` full
        pages). Read-only — pass the result to ``allocate(...,
        shared_prefix=...)`` in the same scheduling tick to claim it (a
        matched page may be a cached *free* page; an intervening fresh
        allocation could evict it). ``keys``: precomputed
        ``prompt_keys(tokens)``, to skip re-hashing."""
        if keys is None:
            keys = self.prompt_keys(tokens)
        pages: list[int] = []
        for key in keys:
            page = self._index.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def register_prefix(self, seq_id: int, tokens,
                        n_tokens: int | None = None, *, keys=None):
        """Index the full pages covering the first ``n_tokens`` of
        ``tokens`` (a prompt) for sequence ``seq_id``. Call only once the
        pages are actually written (the engine registers after each
        prefill chunk). Idempotent: already-indexed prefixes (this
        sequence's own shared pages included) are skipped, and a page
        never carries more than one index entry. ``keys``: precomputed
        ``prompt_keys(tokens)``, to skip re-hashing."""
        if seq_id not in self._seq_pages:
            raise KeyError(f"seq {seq_id}: not live, cannot register")
        pages = self._seq_pages[seq_id]
        n = len(tokens) if n_tokens is None else min(n_tokens, len(tokens))
        n_full = n // self.page_size
        if keys is None:
            keys = self._page_keys(tokens, n_full)
        for k, key in enumerate(keys[:n_full]):
            if key in self._index or pages[k] in self._page_key:
                continue
            self._index[key] = pages[k]
            self._page_key[pages[k]] = key

    def _evict(self, page: int):
        """Drop the page's prefix-index entry (it is about to be rewritten
        by a fresh owner)."""
        key = self._page_key.pop(page, None)
        if key is not None:
            del self._index[key]

    # -- mutation ------------------------------------------------------------

    def allocate(self, seq_id: int, n_tokens: int, *,
                 shared_prefix=()) -> list[int] | None:
        """Reserve pages for ``n_tokens`` tokens of sequence ``seq_id``
        (worst case up front — no mid-decode OOM, no preemption).

        ``shared_prefix``: physical pages from ``match_prefix`` to map
        into the head of the page list instead of allocating fresh —
        each gets a refcount bump (and a cached free page is pulled back
        out of the free list). Returns the full page list
        ``shared + fresh`` in logical order, or None when the pool can't
        cover the fresh remainder; the caller keeps the request queued.
        A denial is counted once per sequence, not once per retry — the
        engine re-asks every tick. Error paths (bad caller arguments)
        raise before any state change; a None return changes only the
        denial counters.
        """
        if seq_id in self._seq_pages:
            raise KeyError(f"seq {seq_id} already allocated")
        shared = [int(p) for p in shared_prefix]
        total = planner.plan_seq_pages(n_tokens, self.page_size)
        if len(shared) > total:
            raise ValueError(
                f"seq {seq_id}: shared_prefix has {len(shared)} pages but "
                f"{n_tokens} tokens only need {total}")
        # validate every shared page BEFORE mutating anything: a failure
        # here must not leak pages popped for earlier entries
        seen: set[int] = set()
        for p in shared:
            if not 0 <= p < self.n_pages or p in seen:
                raise ValueError(
                    f"seq {seq_id}: shared_prefix page {p} out of range "
                    f"or duplicated")
            if self._ref[p] == 0 and p not in self._page_key:
                raise ValueError(
                    f"seq {seq_id}: shared_prefix page {p} is neither "
                    f"live nor prefix-indexed (stale match?)")
            seen.add(p)
        n_fresh = total - len(shared)
        revive = [p for p in shared if self._ref[p] == 0]
        self.stats.alloc_calls += 1
        # revived cached pages leave the free list too — budget both
        if n_fresh + len(revive) > len(self._free):
            if seq_id not in self._denied:
                self._denied.add(seq_id)
                self.stats.admission_denials += 1
            return None
        self._denied.discard(seq_id)
        for p in revive:
            self._free.remove(p)
        fresh = [self._free.pop() for _ in range(n_fresh)]
        for p in fresh:
            self._evict(p)              # content dies with the new owner
            self._ref[p] = 1
        for p in shared:
            self._ref[p] += 1
        pages = shared + fresh
        self._seq_pages[seq_id] = pages
        self.stats.pages_in_use += n_fresh + len(revive)
        self.stats.prefix_pages_shared += len(shared)
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.stats.pages_in_use)
        return pages

    def release(self, seq_id: int) -> int:
        """Drop a finished sequence's reference on each of its pages;
        pages whose refcount hits zero return to the free list. Returns
        the number of pages actually freed (shared pages with surviving
        owners stay in use). Freed pages keep their prefix-index entry
        until the free list reissues them — the cheap eviction policy that
        lets a later request with the same prompt revive them.

        Raises a descriptive ``KeyError`` when ``seq_id`` has no live
        allocation — a double release or a never-admitted sequence. This
        is deliberately an error rather than an idempotent no-op: the
        engine releases exactly once per finished sequence, so a stray
        release means a scheduler bug that silent page accounting would
        hide. Stats are untouched on the error path."""
        if seq_id not in self._seq_pages:
            raise KeyError(
                f"seq {seq_id}: no live page allocation to release "
                f"(double release, or never admitted); live seqs: "
                f"{sorted(self._seq_pages)}")
        pages = self._seq_pages.pop(seq_id)
        freed = 0
        for p in reversed(pages):       # LIFO: tail pages reissue first
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                freed += 1
        self.stats.pages_in_use -= freed
        self.stats.release_calls += 1
        return freed

    def block_table_row(self, seq_id: int, width: int) -> np.ndarray:
        """(width,) int32 physical-page row for the device block table.
        Slots past the sequence's allocation point at page 0 — the kernel
        masks them via the context length, never reads them as data."""
        pages = self._seq_pages.get(seq_id, [])
        if len(pages) > width:
            raise ValueError(f"seq {seq_id}: {len(pages)} pages > table "
                             f"width {width}")
        row = np.zeros(width, np.int32)
        row[:len(pages)] = pages
        return row

    # -- consistency ---------------------------------------------------------

    def validate(self):
        """Assert every internal invariant (tests call this after each
        mutation): page conservation, refcount == number of owning
        sequences, free list exactness, index/inverse agreement, stats
        coherence. Raises AssertionError on the first violation."""
        held: dict[int, int] = {}
        for pages in self._seq_pages.values():
            assert len(set(pages)) == len(pages), "page twice in one seq"
            for p in pages:
                held[p] = held.get(p, 0) + 1
        for p in range(self.n_pages):
            assert self._ref[p] == held.get(p, 0), \
                f"page {p}: ref {self._ref[p]} != owners {held.get(p, 0)}"
        assert len(self._free) == len(set(self._free)), "free-list dup"
        assert all(self._ref[p] == 0 for p in self._free), \
            "live page on the free list"
        assert len(self._free) + sum(r > 0 for r in self._ref) \
            == self.n_pages, "page conservation violated"
        assert self.stats.pages_in_use == sum(r > 0 for r in self._ref)
        assert 0 <= self.stats.pages_in_use <= self.stats.peak_pages_in_use
        assert self.stats.peak_pages_in_use <= self.n_pages
        for key, p in self._index.items():
            assert self._page_key.get(p) == key, "index/inverse mismatch"
        for p, key in self._page_key.items():
            assert self._index.get(key) == p, "inverse/index mismatch"
