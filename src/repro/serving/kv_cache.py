"""Paged KV cache: block-table allocation over a fixed pool of KV pages.

The dense engine's memory bill is ``batch_slots x max_seq`` cache rows no
matter how short the actual sequences are — the padded-waste problem
RedMulE/FantastIC4 attack with adaptive sizing. Here the cache is a fixed
pool of fixed-size *pages* (the device arrays live in the model's cache
pytree, shaped ``(n_pages, Hkv, page_size, dh)`` per layer); this module is
the **host-side** allocator that maps sequences onto pages:

  * each sequence owns an ordered list of physical page indices; logical
    token position ``p`` lives at ``(pages[p // page_size], p % page_size)``
  * a free list recycles pages the moment a sequence finishes (LIFO, so
    recently-touched pages are reused first)
  * admission asks ``can_admit(n_tokens)`` — a request whose worst-case
    footprint exceeds the currently free pages stays queued instead of
    crashing or evicting others

The *device* side consumes only the ``block_table`` this produces: an
``(n_seqs, pages_per_seq)`` int32 array of physical page indices that the
paged-attention kernel uses to gather K/V (see kernels/paged_attention.py).
Unused table slots point at page 0 and are masked by the context length.

Sizing (all byte helpers return bytes; counts are tokens/pages):
``kv_bytes_per_token`` x ``page_size`` x ``n_pages`` is the whole pool —
derived from the *actual* cache dtype (and the codes+scale layout when the
pool is quantized); see docs/SERVING.md for a worked example.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import spx

__all__ = ["PagePool", "kv_bytes_per_token", "pool_bytes", "PoolStats"]


def _elem_bytes(cache_dtype) -> int:
    """Element width in bytes from a dtype (or a raw int, kept for the old
    ``dtype_bytes`` call style)."""
    if isinstance(cache_dtype, int):
        return cache_dtype
    return int(np.dtype(cache_dtype).itemsize)


def kv_bytes_per_token(cfg, cache_dtype=4, *,
                       kv_scheme: str | None = None) -> int:
    """Bytes of K+V cache one token occupies across every attention layer.

    ``cfg``: an ArchConfig; ``cache_dtype``: the dtype the cache arrays are
    actually allocated with (e.g. ``jnp.float32``/``jnp.bfloat16`` — pass
    whatever went to ``init_caches``/``paged_init_caches``; a raw byte
    count is accepted for back-compat). ``kv_scheme`` set (any core/spx
    scheme name) switches to the quantized codes+scale layout: 1 byte of
    uint8 code per element plus a 4-byte f32 scale per (token, KV head)
    side — ``cache_dtype`` is then ignored, matching the allocation.
    Counts attention mixers only — SSM slots carry O(1) state, not
    per-token KV.
    """
    n_attn = sum(1 for s in cfg.pattern
                 if s.split("+")[0] in ("attn", "xdec"))
    if kv_scheme is not None:
        per_head = spx.kv_token_side_bytes(cfg.dh)   # codes + f32 scale
    else:
        per_head = cfg.dh * _elem_bytes(cache_dtype)
    return 2 * cfg.n_periods * n_attn * cfg.n_kv_heads * per_head


def pool_bytes(cfg, n_pages: int, page_size: int, cache_dtype=4, *,
               kv_scheme: str | None = None) -> int:
    """Total device bytes of the paged K/V pool (all layers) — equal by
    construction to the summed ``.nbytes`` of the arrays
    ``models.lm.paged_init_caches`` allocates for the same geometry
    (regression-tested)."""
    return n_pages * page_size * kv_bytes_per_token(cfg, cache_dtype,
                                                    kv_scheme=kv_scheme)


@dataclasses.dataclass
class PoolStats:
    """Allocator counters. Pages are counted in pages, not bytes."""
    n_pages: int
    page_size: int
    pages_in_use: int = 0
    peak_pages_in_use: int = 0
    alloc_calls: int = 0
    release_calls: int = 0
    admission_denials: int = 0      # distinct sequences denied, not ticks

    @property
    def occupancy(self) -> float:
        return self.pages_in_use / self.n_pages

    @property
    def peak_occupancy(self) -> float:
        return self.peak_pages_in_use / self.n_pages


class PagePool:
    """Host-side page allocator: free list + per-sequence page lists.

    Deterministic (LIFO free list), single-threaded — the engine drives it
    from its scheduling loop. All methods are O(pages touched).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError((n_pages, page_size))
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._seq_pages: dict[int, list[int]] = {}
        self._denied: set[int] = set()
        self.stats = PoolStats(n_pages, page_size)

    # -- queries -------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` tokens (ceil)."""
        return -(-n_tokens // self.page_size)

    def free_pages(self) -> int:
        return len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        """Would ``allocate`` succeed for a new ``n_tokens``-token
        reservation right now?"""
        return self.pages_for(n_tokens) <= len(self._free)

    def seq_page_count(self, seq_id: int) -> int:
        return len(self._seq_pages.get(seq_id, ()))

    # -- mutation ------------------------------------------------------------

    def allocate(self, seq_id: int, n_tokens: int) -> list[int] | None:
        """Reserve pages for ``n_tokens`` tokens of sequence ``seq_id``
        (worst case up front — no mid-decode OOM, no preemption). Returns
        the physical page list, or None when the pool can't cover it; the
        caller keeps the request queued. A denial is counted once per
        sequence, not once per retry — the engine re-asks every tick."""
        if seq_id in self._seq_pages:
            raise KeyError(f"seq {seq_id} already allocated")
        need = self.pages_for(n_tokens)
        self.stats.alloc_calls += 1
        if need > len(self._free):
            if seq_id not in self._denied:
                self._denied.add(seq_id)
                self.stats.admission_denials += 1
            return None
        self._denied.discard(seq_id)
        pages = [self._free.pop() for _ in range(need)]
        self._seq_pages[seq_id] = pages
        self.stats.pages_in_use += need
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.stats.pages_in_use)
        return pages

    def release(self, seq_id: int) -> int:
        """Return a finished sequence's pages to the free list. Returns the
        number of pages reclaimed.

        Raises a descriptive ``KeyError`` when ``seq_id`` has no live
        allocation — a double release or a never-admitted sequence. This
        is deliberately an error rather than an idempotent no-op: the
        engine releases exactly once per finished sequence, so a stray
        release means a scheduler bug that silent page accounting would
        hide. Stats are untouched on the error path."""
        if seq_id not in self._seq_pages:
            raise KeyError(
                f"seq {seq_id}: no live page allocation to release "
                f"(double release, or never admitted); live seqs: "
                f"{sorted(self._seq_pages)}")
        pages = self._seq_pages.pop(seq_id)
        self._free.extend(reversed(pages))
        self.stats.pages_in_use -= len(pages)
        self.stats.release_calls += 1
        return len(pages)

    def block_table_row(self, seq_id: int, width: int) -> np.ndarray:
        """(width,) int32 physical-page row for the device block table.
        Slots past the sequence's allocation point at page 0 — the kernel
        masks them via the context length, never reads them as data."""
        pages = self._seq_pages.get(seq_id, [])
        if len(pages) > width:
            raise ValueError(f"seq {seq_id}: {len(pages)} pages > table "
                             f"width {width}")
        row = np.zeros(width, np.int32)
        row[:len(pages)] = pages
        return row
