"""Paged KV cache: block-table allocation over a fixed pool of KV pages.

The dense engine's memory bill is ``batch_slots x max_seq`` cache rows no
matter how short the actual sequences are — the padded-waste problem
RedMulE/FantastIC4 attack with adaptive sizing. Here the cache is a fixed
pool of fixed-size *pages* (the device arrays live in the model's cache
pytree, shaped ``(n_pages, Hkv, page_size, dh)`` per layer); this module is
the **host-side** allocator that maps sequences onto pages:

  * each sequence owns an ordered list of physical page indices; logical
    token position ``p`` lives at ``(pages[p // page_size], p % page_size)``
  * pages are **reference counted**: a full page holding a page-aligned
    block of prompt tokens is immutable once written, so later requests
    with the same prompt prefix map the *same* physical page instead of
    re-prefilling it (``match_prefix``/``register_prefix`` keep a prefix
    index of chain hashes over page-aligned token blocks); a page recycles
    only when its refcount hits zero
  * a free list recycles pages the moment their last owner finishes (LIFO,
    so recently-touched pages are reused first). A freed page *stays in
    the prefix index* until the free list hands it out again (lazy
    eviction) — a system prompt survives in the pool between request
    waves for free
  * admission asks ``can_admit(n_tokens)`` — a request whose worst-case
    footprint exceeds the currently free pages stays queued instead of
    crashing or evicting others
  * the pool is **two-tier**: a preempted sequence's written pages can be
    ``offload``-ed to a host-memory tier (the engine snapshots the device
    bytes and hands them over as an opaque payload; the device pages are
    released ref-aware) and later ``onload``-ed back into freshly
    allocated device pages — the accounting here guarantees no double
    offload and exact free-list recovery, the engine guarantees the
    restored bytes are the written bytes
  * the prefix index is optionally **capacity-bounded**
    (``cache_pages=``): cached-free pages (refcount zero but still
    indexed) beyond the bound are evicted least-recently-used first, and
    fresh allocations prefer un-indexed free pages so a hot cached prefix
    is the last thing recycled (ref-aware eviction). Lookup/hit/eviction
    counters live in ``PoolStats``.

The *device* side consumes only the ``block_table`` this produces: an
``(n_seqs, pages_per_seq)`` int32 array of physical page indices that the
paged-attention kernel uses to gather K/V (see kernels/paged_attention.py).
Unused table slots point at page 0 and are masked by the context length.
Shared pages appear in several rows at once — the device neither knows nor
cares; ownership and copy-on-write live here and in the engine.

Sizing (all byte helpers return bytes; counts are tokens/pages):
``kv_bytes_per_token`` x ``page_size`` x ``n_pages`` is the whole pool —
derived from the *actual* cache dtype (and the codes+scale layout when the
pool is quantized); see docs/SERVING.md for a worked example.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import spx
from repro.runtime import planner

__all__ = [
    "PagePool", "StateCache", "PoolStats", "StateStats",
    "kv_bytes_per_token", "pool_bytes",
    "ssm_state_bytes_per_seq", "cross_kv_bytes_per_seq",
]


def _elem_bytes(cache_dtype=None, dtype_bytes: int | None = None) -> int:
    """Element width in bytes from an explicit dtype OR an explicit byte
    count — exactly one of the two. A raw int passed as ``cache_dtype`` is
    rejected (``np.dtype(2)`` would silently parse as float64): byte
    widths go through ``dtype_bytes=``."""
    if (cache_dtype is None) == (dtype_bytes is None):
        raise ValueError(
            "pass exactly one of cache_dtype= (a dtype such as "
            "jnp.bfloat16) or dtype_bytes= (an int byte width); got "
            f"cache_dtype={cache_dtype!r}, dtype_bytes={dtype_bytes!r}")
    if dtype_bytes is not None:
        if not isinstance(dtype_bytes, int) or dtype_bytes <= 0:
            raise ValueError(f"dtype_bytes must be a positive int, got "
                             f"{dtype_bytes!r}")
        return dtype_bytes
    if isinstance(cache_dtype, int):
        raise ValueError(
            f"cache_dtype={cache_dtype!r} is a raw int — ambiguous "
            f"(np.dtype(2) parses as float64, not 2 bytes). Pass a real "
            f"dtype, or the byte width via dtype_bytes=")
    return int(np.dtype(cache_dtype).itemsize)


def kv_bytes_per_token(cfg, cache_dtype=None, *,
                       dtype_bytes: int | None = None,
                       kv_scheme: str | None = None) -> int:
    """Bytes of K+V cache one token occupies across every attention layer.

    ``cfg``: an ArchConfig; ``cache_dtype``: the dtype the cache arrays
    are actually allocated with (e.g. ``jnp.float32``/``jnp.bfloat16`` —
    pass whatever went to ``init_caches``/``paged_init_caches``);
    ``dtype_bytes``: an explicit element byte width, mutually exclusive
    with ``cache_dtype``. ``kv_scheme`` set (any core/spx scheme name)
    switches to the quantized codes+scale layout: 1 byte of uint8 code per
    element plus a 4-byte f32 scale per (token, KV head) side — the dtype
    arguments are then ignored (and may be omitted), matching the
    allocation. Counts attention mixers only — SSM slots carry O(1) state
    (``ssm_state_bytes_per_seq``) and cross-attention KV is per-sequence
    (``cross_kv_bytes_per_seq``), not per-token.
    """
    n_attn = sum(1 for s in cfg.pattern
                 if s.split("+")[0] in ("attn", "xdec"))
    if kv_scheme is not None:
        per_head = spx.kv_token_side_bytes(cfg.dh)   # codes + f32 scale
    else:
        per_head = cfg.dh * _elem_bytes(cache_dtype, dtype_bytes)
    return 2 * cfg.n_periods * n_attn * cfg.n_kv_heads * per_head


def pool_bytes(cfg, n_pages: int, page_size: int, cache_dtype=None, *,
               dtype_bytes: int | None = None,
               kv_scheme: str | None = None) -> int:
    """Total device bytes of the paged K/V pool (all layers) — equal by
    construction to the summed ``.nbytes`` of the arrays
    ``models.lm.paged_init_caches`` allocates for the same geometry
    (regression-tested)."""
    return n_pages * page_size * kv_bytes_per_token(
        cfg, cache_dtype, dtype_bytes=dtype_bytes, kv_scheme=kv_scheme)


def ssm_state_bytes_per_seq(cfg, cache_dtype=None, *,
                            dtype_bytes: int | None = None) -> int:
    """Bytes of recurrent state one sequence pins across every SSM slot —
    the per-slab bill of the StateCache slab region. O(1) in sequence
    length: a mamba slot is a selective-scan ``h`` (f32) plus a conv
    window, an mLSTM slot is the (C, n, m) matrix-memory triplet (f32)
    plus a conv window, an sLSTM slot is four per-head f32 vectors.
    ``cache_dtype``/``dtype_bytes`` size the conv windows (they live in
    the cache dtype); the scan/cell states are f32 by construction.
    Returns 0 for attention-only patterns."""
    mixers = [s.split("+")[0] for s in cfg.pattern]
    if not any(m in ("mamba", "mlstm", "slstm") for m in mixers):
        return 0
    eb = _elem_bytes(cache_dtype, dtype_bytes)
    di = cfg.ssm_expand * cfg.d_model
    dc, ds, nh = cfg.ssm_d_conv, cfg.ssm_d_state, cfg.lstm_heads
    per_period = 0
    for m in mixers:
        if m == "mamba":
            per_period += 4 * di * ds + eb * (dc - 1) * di
        elif m == "mlstm":
            dh = di // nh
            per_period += 4 * (nh * dh * dh + nh * dh + nh) \
                + eb * (dc - 1) * di
        elif m == "slstm":
            per_period += 4 * 4 * nh * (cfg.d_model // nh)
    return cfg.n_periods * per_period


def cross_kv_bytes_per_seq(cfg, cache_dtype=None, *,
                           dtype_bytes: int | None = None) -> int:
    """Bytes of read-only cross-attention K+V one sequence references —
    the per-slot bill of the StateCache cross region (shared across
    sequences decoding the same input frames, so the *peak* bill is
    ``peak_cross_in_use`` slots, not one per sequence). Returns 0 for
    patterns without an ``xdec`` mixer."""
    n_xdec = sum(1 for s in cfg.pattern if s.split("+")[0] == "xdec")
    if n_xdec == 0:
        return 0
    eb = _elem_bytes(cache_dtype, dtype_bytes)
    return 2 * cfg.n_periods * n_xdec * cfg.n_kv_heads \
        * cfg.enc_seq_len * cfg.dh * eb


@dataclasses.dataclass
class PoolStats:
    """Allocator counters. Pages are counted in pages, not bytes;
    ``pages_in_use`` counts *distinct physical* pages (a page shared by
    three sequences counts once — that is the whole point of sharing)."""
    n_pages: int
    page_size: int
    pages_in_use: int = 0
    peak_pages_in_use: int = 0
    alloc_calls: int = 0
    release_calls: int = 0
    admission_denials: int = 0      # distinct sequences denied, not ticks
    prefix_pages_shared: int = 0    # cumulative refcount bumps from sharing
    # host tier (preemption offload)
    host_pages_in_use: int = 0      # pages of offloaded KV held on host
    peak_host_pages: int = 0
    offload_calls: int = 0
    onload_calls: int = 0
    # prefix-cache economics
    prefix_lookups: int = 0         # match_prefix calls
    prefix_hits: int = 0            # ... that returned >= 1 page
    prefix_evictions: int = 0       # index entries dropped (LRU + reuse)

    @property
    def occupancy(self) -> float:
        return self.pages_in_use / self.n_pages if self.n_pages else 0.0

    @property
    def peak_occupancy(self) -> float:
        return self.peak_pages_in_use / self.n_pages if self.n_pages \
            else 0.0


class PagePool:
    """Host-side page allocator: free list + refcounts + per-sequence page
    lists + prefix index.

    Deterministic (LIFO free list), single-threaded — the engine drives it
    from its scheduling loop. All methods are O(pages touched), except the
    O(pool) free-list removal when a cached free page is revived and the
    O(prefix tokens) hashing in ``match_prefix``/``register_prefix``.

    Mutations are transactional: every failure path — a capacity denial
    (returns None) or a caller error (raises) — leaves the free list,
    refcounts, sequence maps, prefix index and stats exactly as they were
    before the call. Validation runs before the first pop, so a partial
    allocation can never leak pages (regression-tested).

    ``host_pages`` bounds the host tier (pages of offloaded KV that may
    sit in host memory at once; None = unbounded). ``cache_pages`` bounds
    the prefix cache (cached-free indexed pages; None = the original lazy
    policy: entries survive until the page is physically reused).
    """

    # pure-SSM StateCaches run pageless (n_pages == 0); the plain PagePool
    # keeps requiring at least one page
    _min_pages = 1
    _stats_cls = PoolStats

    def __init__(self, n_pages: int, page_size: int, *,
                 host_pages: int | None = None,
                 cache_pages: int | None = None):
        if n_pages < self._min_pages or page_size <= 0:
            raise ValueError((n_pages, page_size))
        if host_pages is not None and host_pages < 0:
            raise ValueError(f"host_pages must be >= 0, got {host_pages}")
        if cache_pages is not None and cache_pages < 0:
            raise ValueError(f"cache_pages must be >= 0, got {cache_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        self.host_pages = host_pages
        self.cache_pages = cache_pages
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._ref: list[int] = [0] * n_pages
        self._seq_pages: dict[int, list[int]] = {}
        # host tier: seq -> (pages of KV parked on host, opaque payload —
        # the engine stores the snapshotted device bytes here)
        self._host_seqs: dict[int, tuple[int, object]] = {}
        # prefix index: chain hash of a page-aligned token prefix -> the
        # physical page holding its last block. _page_key is the inverse
        # (a page carries at most one index entry) so eviction is O(1).
        self._index: dict[bytes, int] = {}
        self._page_key: dict[int, bytes] = {}
        # LRU clock for cached prefixes: page -> last-touched tick
        # (touched on register / match / share / revive)
        self._tick = 0
        self._touched: dict[int, int] = {}
        self._denied: set[int] = set()
        self.stats = self._stats_cls(n_pages, page_size)

    # -- queries -------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` tokens (ceil) with no shared
        prefix — the planner owns the page-count model."""
        return planner.plan_seq_pages(n_tokens, self.page_size)

    def free_pages(self) -> int:
        return len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        """Would ``allocate`` succeed for a new ``n_tokens``-token
        reservation right now (no shared prefix)?"""
        return self.pages_for(n_tokens) <= len(self._free)

    def seq_page_count(self, seq_id: int) -> int:
        return len(self._seq_pages.get(seq_id, ()))

    def seq_pages(self, seq_id: int) -> tuple[int, ...]:
        """The sequence's physical page list (copy; () when not live)."""
        return tuple(self._seq_pages.get(seq_id, ()))

    def ref_count(self, page: int) -> int:
        """Live owners of a physical page (0 = free or cached-free)."""
        return self._ref[page]

    def cached_prefix_pages(self) -> int:
        """Pages currently carrying a prefix-index entry (live + cached)."""
        return len(self._index)

    # -- prefix index --------------------------------------------------------

    def _page_keys(self, tokens, n_full: int) -> list[bytes]:
        """Chain keys for the first ``n_full`` page-aligned blocks of
        ``tokens``: key k hashes blocks 0..k, so equal keys mean equal
        *prefixes*, not just equal blocks (positional KV — RoPE — makes a
        block's cache content depend on everything before it)."""
        t = np.ascontiguousarray(np.asarray(tokens, dtype=np.int32))
        h = hashlib.sha1()
        keys = []
        for k in range(n_full):
            h.update(t[k * self.page_size:(k + 1) * self.page_size]
                     .tobytes())
            keys.append(h.digest())
        return keys

    def prompt_keys(self, tokens) -> list[bytes]:
        """Chain keys for every full page of ``tokens``. Hashing is O(len)
        — compute once per prompt and hand the result to ``match_prefix``
        / ``register_prefix`` so a blocked queue head retried every tick
        (or a prompt registered chunk by chunk) doesn't re-hash from
        block 0 each time."""
        return self._page_keys(tokens, len(tokens) // self.page_size)

    def match_prefix(self, tokens, *, keys=None) -> list[int]:
        """Physical pages holding the longest indexed page-aligned prefix
        of ``tokens`` (possibly all ``len(tokens) // page_size`` full
        pages). Read-only — pass the result to ``allocate(...,
        shared_prefix=...)`` in the same scheduling tick to claim it (a
        matched page may be a cached *free* page; an intervening fresh
        allocation could evict it). ``keys``: precomputed
        ``prompt_keys(tokens)``, to skip re-hashing."""
        if keys is None:
            keys = self.prompt_keys(tokens)
        pages: list[int] = []
        for key in keys:
            page = self._index.get(key)
            if page is None:
                break
            pages.append(page)
        self.stats.prefix_lookups += 1
        if pages:
            self.stats.prefix_hits += 1
            self._tick += 1
            for p in pages:
                self._touched[p] = self._tick
        return pages

    def register_prefix(self, seq_id: int, tokens,
                        n_tokens: int | None = None, *, keys=None):
        """Index the full pages covering the first ``n_tokens`` of
        ``tokens`` (a prompt) for sequence ``seq_id``. Call only once the
        pages are actually written (the engine registers after each
        prefill chunk). Idempotent: already-indexed prefixes (this
        sequence's own shared pages included) are skipped, and a page
        never carries more than one index entry. ``keys``: precomputed
        ``prompt_keys(tokens)``, to skip re-hashing."""
        if seq_id not in self._seq_pages:
            raise KeyError(f"seq {seq_id}: not live, cannot register")
        pages = self._seq_pages[seq_id]
        n = len(tokens) if n_tokens is None else min(n_tokens, len(tokens))
        n_full = n // self.page_size
        if keys is None:
            keys = self._page_keys(tokens, n_full)
        self._tick += 1
        for k, key in enumerate(keys[:n_full]):
            page = pages[k]
            if key in self._index or page in self._page_key:
                if page in self._page_key:
                    self._touched[page] = self._tick
                continue
            self._index[key] = page
            self._page_key[page] = key
            self._touched[page] = self._tick

    def _evict(self, page: int):
        """Drop the page's prefix-index entry (it is about to be rewritten
        by a fresh owner, or LRU-evicted past ``cache_pages``)."""
        key = self._page_key.pop(page, None)
        if key is not None:
            del self._index[key]
            self._touched.pop(page, None)
            self.stats.prefix_evictions += 1

    def _pop_fresh(self) -> int:
        """Pop a free page for a fresh allocation, preferring un-indexed
        pages (LIFO among those) so hot cached prefixes are the last thing
        recycled; when every free page carries a cached prefix, recycle
        the least-recently-touched one."""
        for i in range(len(self._free) - 1, -1, -1):
            if self._free[i] not in self._page_key:
                return self._free.pop(i)
        i = min(range(len(self._free)),
                key=lambda j: self._touched.get(self._free[j], 0))
        return self._free.pop(i)

    def _enforce_cache_capacity(self):
        """Evict cached-free prefix pages (refcount zero but still
        indexed) past the ``cache_pages`` bound, coldest first. Pages
        pinned by live owners never count against the bound — their index
        entries are free to keep (ref-aware)."""
        if self.cache_pages is None:
            return
        cached = [p for p in self._page_key if self._ref[p] == 0]
        while len(cached) > self.cache_pages:
            victim = min(cached, key=lambda p: self._touched.get(p, 0))
            self._evict(victim)
            cached.remove(victim)

    # -- mutation ------------------------------------------------------------

    def allocate(self, seq_id: int, n_tokens: int, *,
                 shared_prefix=()) -> list[int] | None:
        """Reserve pages for ``n_tokens`` tokens of sequence ``seq_id``
        (worst case up front — no mid-decode OOM, no preemption).

        ``shared_prefix``: physical pages from ``match_prefix`` to map
        into the head of the page list instead of allocating fresh —
        each gets a refcount bump (and a cached free page is pulled back
        out of the free list). Returns the full page list
        ``shared + fresh`` in logical order, or None when the pool can't
        cover the fresh remainder; the caller keeps the request queued.
        A denial is counted once per sequence, not once per retry — the
        engine re-asks every tick. Error paths (bad caller arguments)
        raise before any state change; a None return changes only the
        denial counters.
        """
        if seq_id in self._seq_pages:
            raise KeyError(f"seq {seq_id} already allocated")
        shared = [int(p) for p in shared_prefix]
        total = planner.plan_seq_pages(n_tokens, self.page_size)
        if len(shared) > total:
            raise ValueError(
                f"seq {seq_id}: shared_prefix has {len(shared)} pages but "
                f"{n_tokens} tokens only need {total}")
        # validate every shared page BEFORE mutating anything: a failure
        # here must not leak pages popped for earlier entries
        seen: set[int] = set()
        for p in shared:
            if not 0 <= p < self.n_pages or p in seen:
                raise ValueError(
                    f"seq {seq_id}: shared_prefix page {p} out of range "
                    f"or duplicated")
            if self._ref[p] == 0 and p not in self._page_key:
                raise ValueError(
                    f"seq {seq_id}: shared_prefix page {p} is neither "
                    f"live nor prefix-indexed (stale match?)")
            seen.add(p)
        n_fresh = total - len(shared)
        revive = [p for p in shared if self._ref[p] == 0]
        self.stats.alloc_calls += 1
        # revived cached pages leave the free list too — budget both
        if n_fresh + len(revive) > len(self._free):
            if seq_id not in self._denied:
                self._denied.add(seq_id)
                self.stats.admission_denials += 1
            return None
        self._denied.discard(seq_id)
        for p in revive:
            self._free.remove(p)
        fresh = [self._pop_fresh() for _ in range(n_fresh)]
        for p in fresh:
            self._evict(p)              # content dies with the new owner
            self._ref[p] = 1
        self._tick += 1
        for p in shared:
            self._ref[p] += 1
            if p in self._page_key:
                self._touched[p] = self._tick
        pages = shared + fresh
        self._seq_pages[seq_id] = pages
        self.stats.pages_in_use += n_fresh + len(revive)
        self.stats.prefix_pages_shared += len(shared)
        self.stats.peak_pages_in_use = max(self.stats.peak_pages_in_use,
                                           self.stats.pages_in_use)
        return pages

    def release(self, seq_id: int) -> int:
        """Drop a finished sequence's reference on each of its pages;
        pages whose refcount hits zero return to the free list. Returns
        the number of pages actually freed (shared pages with surviving
        owners stay in use). Freed pages keep their prefix-index entry
        until the free list reissues them — the cheap eviction policy that
        lets a later request with the same prompt revive them.

        Raises a descriptive ``KeyError`` when ``seq_id`` has no live
        allocation — a double release or a never-admitted sequence. This
        is deliberately an error rather than an idempotent no-op: the
        engine releases exactly once per finished sequence, so a stray
        release means a scheduler bug that silent page accounting would
        hide. Stats are untouched on the error path."""
        if seq_id not in self._seq_pages:
            raise KeyError(
                f"seq {seq_id}: no live page allocation to release "
                f"(double release, or never admitted); live seqs: "
                f"{sorted(self._seq_pages)}")
        pages = self._seq_pages.pop(seq_id)
        freed = 0
        for p in reversed(pages):       # LIFO: tail pages reissue first
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                freed += 1
        self.stats.pages_in_use -= freed
        self.stats.release_calls += 1
        self._enforce_cache_capacity()
        return freed

    # -- host tier (preemption offload) --------------------------------------

    def can_offload(self, n_pages: int) -> bool:
        """Would the host tier accept ``n_pages`` more pages right now?"""
        if self.host_pages is None:
            return True
        return self.stats.host_pages_in_use + n_pages <= self.host_pages

    def releasable_pages(self, seq_id: int) -> int:
        """Device pages an offload of this sequence would actually free:
        owned pages whose only reference is this sequence (shared prefix
        pages stay resident for their other owners)."""
        return sum(1 for p in self._seq_pages.get(seq_id, ())
                   if self._ref[p] == 1)

    def host_resident(self, seq_id: int) -> bool:
        return seq_id in self._host_seqs

    def host_payload_pages(self, seq_id: int) -> int:
        """Host pages the offloaded sequence occupies (0 if not parked)."""
        return self._host_seqs.get(seq_id, (0, None))[0]

    def offload(self, seq_id: int, n_host_pages: int,
                payload=None) -> int | None:
        """Park a live sequence's KV on the host tier: drop its device
        references ref-aware (exactly like ``release`` — shared pages
        survive for their other owners) and record ``n_host_pages`` of
        host occupancy plus an opaque ``payload`` (the engine passes the
        snapshotted page bytes; the pool never inspects it).

        Returns the number of device pages actually freed, or None when
        the host tier is full (``host_pages`` bound) — the sequence stays
        live on device, state untouched. Double offload and offload of a
        non-live sequence raise ``KeyError`` (scheduler bugs)."""
        if seq_id in self._host_seqs:
            raise KeyError(f"seq {seq_id}: already offloaded "
                           f"(double offload)")
        if seq_id not in self._seq_pages:
            raise KeyError(f"seq {seq_id}: not live, cannot offload")
        if not 0 <= n_host_pages <= len(self._seq_pages[seq_id]):
            raise ValueError(
                f"seq {seq_id}: n_host_pages {n_host_pages} outside "
                f"[0, {len(self._seq_pages[seq_id])}]")
        if not self.can_offload(n_host_pages):
            return None
        pages = self._seq_pages.pop(seq_id)
        freed = 0
        for p in reversed(pages):       # LIFO, same policy as release
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                freed += 1
        self.stats.pages_in_use -= freed
        self._host_seqs[seq_id] = (n_host_pages, payload)
        self.stats.offload_calls += 1
        self.stats.host_pages_in_use += n_host_pages
        self.stats.peak_host_pages = max(self.stats.peak_host_pages,
                                         self.stats.host_pages_in_use)
        self._enforce_cache_capacity()
        return freed

    def onload(self, seq_id: int, n_tokens: int):
        """Bring an offloaded sequence back onto the device: allocate a
        fresh worst-case ``n_tokens`` reservation (no prefix sharing —
        the restored bytes are private) and hand back
        ``(pages, payload)`` so the engine can scatter the snapshotted
        bytes into the new pages. Returns None on device-capacity denial
        — the sequence stays parked on host, accounting untouched (the
        denial is counted once per sequence, like ``allocate``)."""
        if seq_id not in self._host_seqs:
            raise KeyError(f"seq {seq_id}: not offloaded, cannot onload")
        n_host, payload = self._host_seqs[seq_id]
        pages = self.allocate(seq_id, n_tokens)
        if pages is None:
            return None
        del self._host_seqs[seq_id]
        self.stats.onload_calls += 1
        self.stats.host_pages_in_use -= n_host
        return pages, payload

    def drop_host(self, seq_id: int) -> int:
        """Forget a host-parked sequence without bringing it back — the
        mid-flight cancellation path for preempted-and-parked requests.
        The snapshot payload is dropped and its host occupancy returned
        to the tier. Returns the host pages released. Raises a
        descriptive ``KeyError`` when the sequence is not parked (same
        contract as ``onload``)."""
        if seq_id not in self._host_seqs:
            raise KeyError(f"seq {seq_id}: not offloaded, cannot drop")
        n_host, _ = self._host_seqs.pop(seq_id)
        self.stats.host_pages_in_use -= n_host
        self._denied.discard(seq_id)
        return n_host

    def block_table_row(self, seq_id: int, width: int) -> np.ndarray:
        """(width,) int32 physical-page row for the device block table.
        Slots past the sequence's allocation point at page 0 — the kernel
        masks them via the context length, never reads them as data."""
        pages = self._seq_pages.get(seq_id, [])
        if len(pages) > width:
            raise ValueError(f"seq {seq_id}: {len(pages)} pages > table "
                             f"width {width}")
        row = np.zeros(width, np.int32)
        row[:len(pages)] = pages
        return row

    # -- consistency ---------------------------------------------------------

    def validate(self):
        """Assert every internal invariant (tests call this after each
        mutation): page conservation, refcount == number of owning
        sequences, free list exactness, index/inverse agreement, stats
        coherence. Raises AssertionError on the first violation."""
        held: dict[int, int] = {}
        for pages in self._seq_pages.values():
            assert len(set(pages)) == len(pages), "page twice in one seq"
            for p in pages:
                held[p] = held.get(p, 0) + 1
        for p in range(self.n_pages):
            assert self._ref[p] == held.get(p, 0), \
                f"page {p}: ref {self._ref[p]} != owners {held.get(p, 0)}"
        assert len(self._free) == len(set(self._free)), "free-list dup"
        assert all(self._ref[p] == 0 for p in self._free), \
            "live page on the free list"
        assert len(self._free) + sum(r > 0 for r in self._ref) \
            == self.n_pages, "page conservation violated"
        assert self.stats.pages_in_use == sum(r > 0 for r in self._ref)
        assert 0 <= self.stats.pages_in_use <= self.stats.peak_pages_in_use
        assert self.stats.peak_pages_in_use <= self.n_pages
        for key, p in self._index.items():
            assert self._page_key.get(p) == key, "index/inverse mismatch"
        for p, key in self._page_key.items():
            assert self._index.get(key) == p, "inverse/index mismatch"
        assert set(self._touched) <= set(self._page_key), \
            "LRU clock entry for an un-indexed page"
        # host tier: a sequence lives on exactly one tier, occupancy is the
        # sum of its entries and stays under the bound
        assert not (set(self._host_seqs) & set(self._seq_pages)), \
            "sequence live on device and host at once"
        assert self.stats.host_pages_in_use == \
            sum(n for n, _ in self._host_seqs.values()), \
            "host occupancy out of sync"
        assert self.stats.host_pages_in_use <= self.stats.peak_host_pages \
            or self.stats.peak_host_pages == 0
        if self.host_pages is not None:
            assert self.stats.host_pages_in_use <= self.host_pages, \
                "host tier over capacity"
        if self.cache_pages is not None:
            cached_free = sum(1 for p in self._page_key
                              if self._ref[p] == 0)
            assert cached_free <= self.cache_pages, \
                f"{cached_free} cached-free pages > bound {self.cache_pages}"


@dataclasses.dataclass
class StateStats(PoolStats):
    """PoolStats plus the slab (recurrent SSM state) and cross
    (read-only encoder-output KV) region counters. Slabs are exclusive —
    one per live sequence with SSM slots; cross entries are refcounted and
    shared across sequences decoding the same input frames, so
    ``cross_in_use`` counts *distinct* entries."""
    n_slabs: int = 0
    slabs_in_use: int = 0
    peak_slabs_in_use: int = 0
    n_cross: int = 0
    cross_in_use: int = 0
    peak_cross_in_use: int = 0
    cross_lookups: int = 0          # admissions that needed a cross entry
    cross_hits: int = 0             # ... served from an existing entry
    cross_evictions: int = 0        # cached-free cross entries recycled


class StateCache(PagePool):
    """PagePool generalized into a unified state-cache with three region
    types under one budget, one admission policy, one stats surface:

      * the token-paged KV **page** region inherited from PagePool
        (attention and decoder-self-attention slots);
      * a fixed-size **slab** region for recurrent SSM state: one slab per
        live sequence holds the conv windows and selective-scan/cell
        states of *every* SSM slot x period (the device arrays are shaped
        ``(P, n_slabs, ...)`` per state leaf — see
        ``transformer.slot_init_paged_cache``). Slabs are exclusive
        (recurrent state is written every step, never shareable),
        allocated and released atomically with the sequence's pages, and
        preempt/offload-able: the engine snapshots the slab bytes into the
        offload payload and the slab returns to the free list;
      * a refcounted **cross** region of read-only encoder-output KV
        entries keyed by the input frames (``cross_key``): requests
        decoding the same audio/image share one entry — the enc-dec
        analogue of the prefix cache, reusing the *whole encoder pass*
        across requests. Entries go cached-free on last release (index
        kept, LRU-evicted only when a fresh admission needs the slot).

    Allocation is all-or-nothing across regions: ``allocate`` first
    budget-checks the slab and cross needs, then runs the (transactional)
    page allocation, then commits the slab/cross bookkeeping — a denial in
    any region leaves every region untouched, so a queued request never
    holds a slab while waiting for pages or vice versa.

    ``n_pages=0`` is legal (pure-SSM models run pageless: every
    reservation is 0 pages and ``allocate`` returns ``[]`` — callers must
    test ``pages is None``, never truthiness).
    """

    _min_pages = 0
    _stats_cls = StateStats

    def __init__(self, n_pages: int, page_size: int, *,
                 n_slabs: int = 0, n_cross: int = 0,
                 host_pages: int | None = None,
                 cache_pages: int | None = None):
        if n_slabs < 0 or n_cross < 0:
            raise ValueError((n_slabs, n_cross))
        super().__init__(n_pages, page_size, host_pages=host_pages,
                         cache_pages=cache_pages)
        self.stats.n_slabs = n_slabs
        self.stats.n_cross = n_cross
        self.n_slabs = n_slabs
        self.n_cross = n_cross
        # slab region: exclusive, LIFO free list, one per sequence
        self._slab_free: list[int] = list(range(n_slabs - 1, -1, -1))
        self._seq_slab: dict[int, int] = {}
        # cross region: refcounted + indexed by frames key, LRU shares the
        # page region's _tick clock
        self._cross_free: list[int] = list(range(n_cross - 1, -1, -1))
        self._seq_cross: dict[int, int] = {}
        self._cross_ref: list[int] = [0] * n_cross
        self._cross_index: dict[bytes, int] = {}
        self._cross_key: dict[int, bytes] = {}
        self._cross_touched: dict[int, int] = {}
        # sequences whose cross entry was a MISS: the engine must run the
        # encoder and fill the entry before the first decoder step
        self._cross_fresh: set[int] = set()
        # offloaded sequences that must reacquire a slab at onload
        self._host_needs: dict[int, bool] = {}

    # -- region queries ------------------------------------------------------

    def seq_slab(self, seq_id: int) -> int | None:
        """The sequence's slab index (None when it holds no slab)."""
        return self._seq_slab.get(seq_id)

    def seq_cross(self, seq_id: int) -> int | None:
        """The sequence's cross-entry index (None when it holds none).
        Survives offload — the entry is read-only and possibly shared, so
        parking the sequence on host keeps its reference alive and skips
        the encoder rerun at resume."""
        return self._seq_cross.get(seq_id)

    def consume_cross_fresh(self, seq_id: int) -> bool:
        """True exactly once after an admission whose cross entry was a
        miss: the caller must encode the frames and fill the entry."""
        if seq_id in self._cross_fresh:
            self._cross_fresh.discard(seq_id)
            return True
        return False

    def free_slabs(self) -> int:
        return len(self._slab_free)

    def free_cross(self) -> int:
        return len(self._cross_free)

    # -- cross-region internals ----------------------------------------------

    def _cross_evict(self, slot: int):
        """Drop a cached-free cross entry's index (its slot is about to be
        rewritten by a fresh encoder output)."""
        key = self._cross_key.pop(slot, None)
        if key is not None:
            del self._cross_index[key]
            self._cross_touched.pop(slot, None)
            self.stats.cross_evictions += 1

    def _pop_fresh_cross(self) -> int:
        """Pop a free cross slot, preferring un-indexed slots so hot
        cached encoder outputs are the last thing recycled; else recycle
        the least-recently-touched cached-free one."""
        for i in range(len(self._cross_free) - 1, -1, -1):
            if self._cross_free[i] not in self._cross_key:
                return self._cross_free.pop(i)
        i = min(range(len(self._cross_free)),
                key=lambda j: self._cross_touched.get(self._cross_free[j],
                                                      0))
        return self._cross_free.pop(i)

    # -- unified admission ---------------------------------------------------

    def allocate(self, seq_id: int, n_tokens: int, *, shared_prefix=(),
                 need_slab: bool = False,
                 cross_key: bytes | None = None) -> list[int] | None:
        """PagePool.allocate extended to the slab and cross regions,
        all-or-nothing. ``need_slab``: reserve one SSM-state slab;
        ``cross_key``: the frames hash — a hit maps the existing entry
        (refcount bump), a miss claims a fresh slot and marks the
        sequence ``consume_cross_fresh`` so the engine runs the encoder.
        Returns the page list (possibly ``[]`` on a pageless pool) or
        None when *any* region lacks capacity — no region is touched."""
        if seq_id in self._seq_pages:
            raise KeyError(f"seq {seq_id} already allocated")
        want_slab = need_slab and seq_id not in self._seq_slab
        want_cross = cross_key is not None and seq_id not in self._seq_cross
        cross_hit = (want_cross
                     and self._cross_index.get(cross_key) is not None)
        # a hit revives an existing slot (even cached-free: the slot just
        # leaves the free list); only a miss consumes a free slot
        if (want_slab and not self._slab_free) or \
                (want_cross and not cross_hit and not self._cross_free):
            self.stats.alloc_calls += 1
            if seq_id not in self._denied:
                self._denied.add(seq_id)
                self.stats.admission_denials += 1
            return None
        pages = super().allocate(seq_id, n_tokens,
                                 shared_prefix=shared_prefix)
        if pages is None:
            return None                 # super counted the denial
        if want_slab:
            slab = self._slab_free.pop()
            self._seq_slab[seq_id] = slab
            self.stats.slabs_in_use += 1
            self.stats.peak_slabs_in_use = max(
                self.stats.peak_slabs_in_use, self.stats.slabs_in_use)
        if want_cross:
            self.stats.cross_lookups += 1
            self._tick += 1
            if cross_hit:
                slot = self._cross_index[cross_key]
                self.stats.cross_hits += 1
                if self._cross_ref[slot] == 0:
                    self._cross_free.remove(slot)   # revive cached-free
                    self.stats.cross_in_use += 1
            else:
                slot = self._pop_fresh_cross()
                self._cross_evict(slot)
                self._cross_index[cross_key] = slot
                self._cross_key[slot] = cross_key
                self._cross_fresh.add(seq_id)
                self.stats.cross_in_use += 1
            self._cross_ref[slot] += 1
            self._cross_touched[slot] = self._tick
            self._seq_cross[seq_id] = slot
            self.stats.peak_cross_in_use = max(
                self.stats.peak_cross_in_use, self.stats.cross_in_use)
        return pages

    def release(self, seq_id: int) -> int:
        freed = super().release(seq_id)     # raises if not live
        slab = self._seq_slab.pop(seq_id, None)
        if slab is not None:
            self._slab_free.append(slab)
            self.stats.slabs_in_use -= 1
        slot = self._seq_cross.pop(seq_id, None)
        if slot is not None:
            self._cross_ref[slot] -= 1
            if self._cross_ref[slot] == 0:
                self._cross_free.append(slot)   # cached-free: index kept
                self.stats.cross_in_use -= 1
        self._cross_fresh.discard(seq_id)
        return freed

    # -- host tier ------------------------------------------------------------

    def offload(self, seq_id: int, n_host_pages: int,
                payload=None) -> int | None:
        """Like PagePool.offload, plus: the sequence's slab returns to the
        free list (the engine snapshots the slab bytes into the payload)
        and is reacquired at onload. The cross reference is *kept* — the
        entry is read-only and possibly shared, so resume skips the
        encoder rerun; host occupancy accounting stays pages-only."""
        freed = super().offload(seq_id, n_host_pages, payload)
        if freed is None:
            return None
        slab = self._seq_slab.pop(seq_id, None)
        if slab is not None:
            self._slab_free.append(slab)
            self.stats.slabs_in_use -= 1
        self._host_needs[seq_id] = slab is not None
        return freed

    def onload(self, seq_id: int, n_tokens: int):
        """PagePool.onload, rerouted through the unified ``allocate`` so
        the sequence reacquires a slab when it held one at offload (the
        new slab index may differ — the engine scatters the snapshotted
        bytes wherever ``seq_slab`` now points)."""
        if seq_id not in self._host_seqs:
            raise KeyError(f"seq {seq_id}: not offloaded, cannot onload")
        n_host, payload = self._host_seqs[seq_id]
        pages = self.allocate(seq_id, n_tokens,
                              need_slab=self._host_needs.get(seq_id,
                                                             False))
        if pages is None:
            return None
        del self._host_seqs[seq_id]
        self._host_needs.pop(seq_id, None)
        self.stats.onload_calls += 1
        self.stats.host_pages_in_use -= n_host
        return pages, payload

    def drop_host(self, seq_id: int) -> int:
        """PagePool.drop_host plus the reference ``offload`` deliberately
        retained: a parked sequence keeps its cross entry alive so resume
        skips the encoder rerun, but a *cancelled* one never resumes, so
        the share is released here (the entry goes cached-free at zero
        refs, index kept — revivable by a later request with the same
        frames)."""
        n_host = super().drop_host(seq_id)
        self._host_needs.pop(seq_id, None)
        slot = self._seq_cross.pop(seq_id, None)
        if slot is not None:
            self._cross_ref[slot] -= 1
            if self._cross_ref[slot] == 0:
                self._cross_free.append(slot)   # cached-free: index kept
                self.stats.cross_in_use -= 1
        self._cross_fresh.discard(seq_id)       # never-encoded entry
        return n_host

    # -- consistency ---------------------------------------------------------

    def validate(self):
        super().validate()
        # slab region: conservation, exclusivity, stats agreement
        assert len(self._slab_free) == len(set(self._slab_free)), \
            "slab free-list dup"
        assert len(self._slab_free) + len(self._seq_slab) == self.n_slabs,\
            "slab conservation violated"
        owned = list(self._seq_slab.values())
        assert len(owned) == len(set(owned)), "slab owned twice"
        assert not (set(owned) & set(self._slab_free)), \
            "owned slab on the free list"
        assert self.stats.slabs_in_use == len(self._seq_slab)
        assert set(self._seq_slab) <= set(self._seq_pages), \
            "slab held by a non-live sequence"
        # cross region: refcount == owners (offloaded sequences keep
        # their reference), free list == ref-zero slots, index/inverse
        held: dict[int, int] = {}
        for slot in self._seq_cross.values():
            held[slot] = held.get(slot, 0) + 1
        for slot in range(self.n_cross):
            assert self._cross_ref[slot] == held.get(slot, 0), \
                f"cross {slot}: ref {self._cross_ref[slot]} != owners"
        assert len(self._cross_free) == len(set(self._cross_free)), \
            "cross free-list dup"
        assert all(self._cross_ref[s] == 0 for s in self._cross_free), \
            "live cross entry on the free list"
        assert len(self._cross_free) \
            + sum(r > 0 for r in self._cross_ref) == self.n_cross, \
            "cross conservation violated"
        assert self.stats.cross_in_use == \
            sum(r > 0 for r in self._cross_ref)
        for key, slot in self._cross_index.items():
            assert self._cross_key.get(slot) == key, \
                "cross index/inverse mismatch"
        for slot, key in self._cross_key.items():
            assert self._cross_index.get(key) == slot, \
                "cross inverse/index mismatch"
        assert set(self._cross_fresh) <= set(self._seq_cross), \
            "cross-fresh mark without an entry"
