"""One frozen config object for every serving knob.

``ServeEngine`` grew its knobs one PR at a time — fifteen keyword
arguments, each with its own ``REPRO_*`` env fallback and its own
cross-knob gate scattered through ``__init__``. ``ServeConfig`` collapses
them into a single frozen dataclass; ``ServeConfig.resolve(cfg)`` is the
ONLY place env fallbacks are read and cross-knob validation runs, and it
returns a fully-resolved copy (every ``None``/"auto" replaced by the
concrete value the engine will use). The engine, the replica router,
benches and the CLI all construct from the same resolved object, so a
knob combination is legal or illegal in exactly one place.

Resolution contract (unchanged from the per-kwarg era, now centralized):

* ``None`` means "read the env default, else the built-in default".
* An env-enabled feature **degrades silently** where the architecture or
  layout can't support it (e.g. ``REPRO_PREFIX_CACHE=1`` on a dense
  engine); an **explicit** ``True``/value there is a caller error with
  the failing predicate(s) enumerated.
* ``resolve()`` is idempotent: resolving a resolved config returns it
  unchanged, so plumbing can resolve defensively.

Env knobs owned here: ``REPRO_PREFIX_CACHE``, ``REPRO_SPEC_K``,
``REPRO_FUSED_DECODE``, ``REPRO_SCHEDULER``, ``REPRO_HOST_PAGES``,
``REPRO_PREFIX_CACHE_PAGES``, ``REPRO_PREFILL_CHUNK``, ``REPRO_SHARDS``,
``REPRO_REPLICAS``. (``REPRO_PAGE_SIZE`` stays with the planner: it pins
the *planned* page size for every consumer of ``plan_kv_pages``, not just
the engine.)

Sharding knobs (docs/SERVING.md "Sharded serving"):

* ``shards`` — tensor-parallel width: the engine builds a
  ``(data=1, model=shards)`` mesh, places params by ``ShardingPolicy``
  and head-shards the paged KV/state pools over the model axis.
* ``replicas`` — data-parallel width: a ``ReplicaRouter`` knob (the
  engine itself always runs one replica); each replica gets its own
  engine, device slice and per-replica page budget.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.serving.spec import DEFAULT_SPEC_K

__all__ = ["ServeConfig", "DEFAULT_PREFILL_CHUNK", "LEGACY_KNOBS"]

#: chunk length for chunked prefill when the caller doesn't pass one;
#: REPRO_PREFILL_CHUNK=N overrides. Ragged final chunks are padded up to
#: the next power of two so the engine compiles O(log chunk) variants,
#: not one per prompt length.
DEFAULT_PREFILL_CHUNK = 32

#: the pre-ServeConfig ``ServeEngine.__init__`` keyword knobs — accepted
#: for one PR via a DeprecationWarning shim that forwards them into a
#: ServeConfig (see ServeEngine.__init__).
LEGACY_KNOBS = frozenset({
    "batch_slots", "max_seq", "quantize", "seed", "kv_layout", "page_size",
    "pool_pages", "prefill_chunk", "kv_cache_dtype", "prefix_cache",
    "spec_decode", "spec_k", "fused_decode", "scheduler", "host_pages",
    "prefix_cache_pages", "shards", "replicas",
})


def _decode_pattern_cfg(cfg: ArchConfig) -> ArchConfig:
    """The config whose layer pattern holds serving state (the DECODER
    for enc-dec models)."""
    if cfg.enc_dec:
        from repro.models import encdec as encdec_mod
        return encdec_mod.dec_cfg(cfg)
    return cfg


def _slab_mixers(dcfg: ArchConfig) -> list[str]:
    """The recurrent mixer kinds present in the decode pattern."""
    return sorted({s.split("+")[0] for s in dcfg.pattern}
                  & {"mamba", "mlstm", "slstm"})


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Every ServeEngine knob, in one frozen object. Field semantics are
    identical to the old ``ServeEngine.__init__`` keywords; ``shards`` /
    ``replicas`` are new (sharded serving). Construct with whatever
    subset you care about and let ``resolve()`` fill the rest::

        eng = ServeEngine(params, cfg, ServeConfig(batch_slots=8,
                                                   kv_layout="paged"))
    """
    batch_slots: int = 4
    max_seq: int = 256
    quantize: Optional[str] = "sp2_4"
    seed: int = 0
    kv_layout: str = "auto"
    page_size: Optional[int] = None
    pool_pages: Optional[int] = None
    prefill_chunk: Optional[int] = None
    kv_cache_dtype: Any = "float32"
    prefix_cache: Optional[bool] = None
    spec_decode: Optional[bool] = None
    spec_k: Optional[int] = None
    fused_decode: Optional[bool] = None
    scheduler: Optional[str] = None
    host_pages: Optional[int] = None
    prefix_cache_pages: Optional[int] = None
    #: tensor-parallel width (model-axis mesh size). None = REPRO_SHARDS
    #: env, default 1 (single device).
    shards: Optional[int] = None
    #: data-parallel replica count — consumed by ReplicaRouter, rejected
    #: by a bare ServeEngine. None = REPRO_REPLICAS env, default 1.
    replicas: Optional[int] = None
    #: set by resolve(); resolved configs pass through resolve() unchanged
    resolved: bool = False

    def replace(self, **kw) -> "ServeConfig":
        """Keyword field replacement. Any change invalidates resolution —
        the copy must be resolved again."""
        kw.setdefault("resolved", False)
        return dataclasses.replace(self, **kw)

    # -- resolution ----------------------------------------------------------

    def resolve(self, cfg: ArchConfig) -> "ServeConfig":
        """Return a fully-resolved copy for ``cfg``: env fallbacks read,
        "auto" layouts picked, cross-knob gates checked. Idempotent."""
        if self.resolved:
            return self
        dcfg = _decode_pattern_cfg(cfg)
        mixers = {s.split("+")[0] for s in dcfg.pattern}
        has_slab = bool(mixers & {"mamba", "mlstm", "slstm"})
        has_cross = bool(cfg.enc_dec)

        if self.batch_slots < 1:
            raise ValueError(
                f"batch_slots must be >= 1, got {self.batch_slots}")
        if self.max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {self.max_seq}")

        kv_layout = self.kv_layout
        if kv_layout == "auto":
            # every supported pattern serves paged now (SSM, hybrid,
            # enc-dec, M-RoPE included); dense remains as the
            # differential-test baseline
            kv_layout = "paged"
        if kv_layout not in ("paged", "dense"):
            raise ValueError(
                f"kv_layout must be 'paged', 'dense' or 'auto', "
                f"got {kv_layout!r}")

        # shared-prefix KV page reuse (paged, token-KV-only patterns).
        # None = read the env default; an env-enabled cache degrades
        # silently where unsupported, an explicit True there is a caller
        # error with the actual failing predicate(s) enumerated.
        explicit_prefix = self.prefix_cache is not None
        prefix_cache = self.prefix_cache
        if prefix_cache is None:
            prefix_cache = os.environ.get(
                "REPRO_PREFIX_CACHE", "").lower() in ("1", "true")
        prefix_gaps = []
        if kv_layout != "paged":
            prefix_gaps.append("kv_layout='dense' — per-slot rows, "
                               "nothing to share")
        if has_slab:
            prefix_gaps.append(
                f"recurrent mixer(s) {_slab_mixers(dcfg)} in "
                f"pattern={dcfg.pattern} — slab state is "
                "per-sequence, not per-page")
        if has_cross:
            prefix_gaps.append(
                "enc_dec=True — decoder KV depends on the encoder "
                "output, so prompt pages are not shareable by token "
                "content (the cross region already shares the encoder "
                "pass by frames)")
        if prefix_cache and prefix_gaps:
            if explicit_prefix:
                raise ValueError(
                    "prefix_cache=True is unsupported here: "
                    + "; ".join(prefix_gaps))
            prefix_cache = False

        # speculative decoding (paged only — the verify window rides the
        # paged chunk path). None = read the env default (REPRO_SPEC_K=N
        # enables with window N); passing spec_k alone also enables —
        # a window size IS the intent, silently ignoring it would let a
        # caller benchmark speculation that never ran. Mirroring
        # prefix_cache, an env-enabled default degrades silently for a
        # dense engine; an explicit spec_decode=True (or spec_k=) there
        # is a caller error.
        env_k = int(os.environ.get("REPRO_SPEC_K", "0") or 0)
        raw_k = self.spec_k
        if raw_k == 0 and self.spec_decode is False:
            # the (spec_decode=False, spec_k=0) pair is a resolved "off"
            # config that was replace()d and is being re-resolved; any
            # other explicit zero window stays a caller error below
            raw_k = None
        if raw_k is not None and raw_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {raw_k}")
        if self.spec_decode is False and raw_k is not None:
            raise ValueError(
                f"spec_k={raw_k} with spec_decode=False — drop one")
        explicit_spec = (self.spec_decode is not None
                         or raw_k is not None)
        spec_decode = self.spec_decode
        if spec_decode is None:
            spec_decode = env_k > 0 or raw_k is not None
        spec_gaps = []
        if kv_layout != "paged":
            spec_gaps.append("kv_layout='dense' — the verify step scores "
                             "the draft window through the paged chunk "
                             "path")
        if has_slab:
            spec_gaps.append(
                f"recurrent mixer(s) {_slab_mixers(dcfg)} in "
                f"pattern={dcfg.pattern} — slab updates are "
                "destructive, a rejected draft tail cannot roll back")
        if spec_decode and spec_gaps:
            if explicit_spec:
                raise ValueError("spec_decode is unsupported here: "
                                 + "; ".join(spec_gaps))
            spec_decode = False
        if spec_decode:
            spec_k = (raw_k if raw_k is not None
                      else (env_k or DEFAULT_SPEC_K))
            if spec_k < 1:
                raise ValueError(
                    f"spec_k must be >= 1, got {spec_k} "
                    "(check REPRO_SPEC_K)")
        else:
            spec_k = 0

        # fused ragged-decode megakernel (paged only). Default ON for
        # paged engines (REPRO_FUSED_DECODE=0 opts out); the env default
        # degrades silently for a dense engine while an explicit True
        # there is a caller error.
        explicit_fused = self.fused_decode is not None
        fused_decode = self.fused_decode
        if fused_decode is None:
            fused_decode = os.environ.get(
                "REPRO_FUSED_DECODE", "1").lower() not in ("0", "false")
        if fused_decode and kv_layout != "paged":
            if explicit_fused:
                raise ValueError(
                    "fused_decode=True needs kv_layout='paged' — the "
                    "megakernel decodes through the paged page pools")
            fused_decode = False

        # scheduler: "cb" (continuous batching — priority admission with
        # preemption + KV offload, the paged default) or "fifo" (the
        # synchronous head-blocks-queue baseline). REPRO_SCHEDULER
        # overrides the default; an env-selected "cb" degrades silently
        # to fifo for a dense engine while an explicit one there is a
        # caller error (preemption snapshots live in the page pool — the
        # dense layout has nothing to offload).
        explicit_sched = self.scheduler is not None
        scheduler = self.scheduler
        if scheduler is None:
            scheduler = (os.environ.get("REPRO_SCHEDULER", "")
                         or ("cb" if kv_layout == "paged" else "fifo"))
        if scheduler not in ("fifo", "cb"):
            raise ValueError(
                f"scheduler must be 'fifo' or 'cb', got {scheduler!r} "
                "(check REPRO_SCHEDULER)")
        if scheduler == "cb" and kv_layout != "paged":
            if explicit_sched:
                raise ValueError(
                    "scheduler='cb' needs kv_layout='paged' — preemption "
                    "offloads KV pages and the dense layout has none")
            scheduler = "fifo"

        # two-tier pool knobs (paged only): host_pages bounds the host
        # offload tier, prefix_cache_pages bounds the cached-free prefix
        # index. Same explicit-raise / env-degrade contract.
        env_host = os.environ.get("REPRO_HOST_PAGES", "")
        env_cache = os.environ.get("REPRO_PREFIX_CACHE_PAGES", "")
        explicit_tier = (self.host_pages is not None
                         or self.prefix_cache_pages is not None)
        host_pages = self.host_pages
        prefix_cache_pages = self.prefix_cache_pages
        if host_pages is None and env_host:
            host_pages = int(env_host)
        if prefix_cache_pages is None and env_cache:
            prefix_cache_pages = int(env_cache)
        if kv_layout != "paged" and (host_pages is not None
                                     or prefix_cache_pages is not None):
            if explicit_tier:
                raise ValueError(
                    "host_pages / prefix_cache_pages need "
                    "kv_layout='paged' — the dense layout has no page pool")
            host_pages = prefix_cache_pages = None

        # chunked prefill (paged only; the dense layout prefills whole
        # prompts and ignores the knob, matching the old kwarg behavior)
        prefill_chunk = self.prefill_chunk
        if kv_layout == "paged":
            prefill_chunk = (prefill_chunk
                             or int(os.environ.get("REPRO_PREFILL_CHUNK",
                                                   0))
                             or DEFAULT_PREFILL_CHUNK)
            if prefill_chunk < 1:
                raise ValueError(
                    f"prefill_chunk must be >= 1, got {prefill_chunk} "
                    "(check REPRO_PREFILL_CHUNK)")

        # tensor-parallel width. Same explicit-raise / env-degrade
        # contract: REPRO_SHARDS on a dense engine degrades to 1, an
        # explicit shards= there is a caller error (the sharded engine
        # partitions the *paged* KV/state pools over the model axis).
        explicit_shards = self.shards is not None
        shards = self.shards
        if shards is None:
            shards = int(os.environ.get("REPRO_SHARDS", "1") or 1)
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards} "
                             "(check REPRO_SHARDS)")
        if shards > 1 and kv_layout != "paged":
            if explicit_shards:
                raise ValueError(
                    f"shards={shards} needs kv_layout='paged' — the "
                    "sharded engine head-shards the paged KV/state pools "
                    "over the model axis")
            shards = 1

        replicas = self.replicas
        if replicas is None:
            replicas = int(os.environ.get("REPRO_REPLICAS", "1") or 1)
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas} "
                             "(check REPRO_REPLICAS)")

        return dataclasses.replace(
            self, kv_layout=kv_layout,
            kv_cache_dtype=jnp.dtype(self.kv_cache_dtype),
            prefix_cache=bool(prefix_cache), spec_decode=bool(spec_decode),
            spec_k=spec_k, fused_decode=bool(fused_decode),
            scheduler=scheduler, host_pages=host_pages,
            prefix_cache_pages=prefix_cache_pages,
            prefill_chunk=prefill_chunk, shards=shards, replicas=replicas,
            resolved=True)
