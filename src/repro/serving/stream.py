"""Per-request incremental token delivery for the serving engine.

``ServeEngine.submit`` registers a ``StreamState`` per request;
``ServeEngine.stream(rid)`` hands out ``TokenStream`` views over it. The
engine never copies tokens into a side buffer: a stream reads straight
out of ``Request.output`` behind a cursor, so delivered tokens are
bit-identical to what ``run()`` returns by construction — the stream
surface changes *when* a consumer sees a token, never *what* the token
is (regression-tested across the paged x SPx x spec x cb matrix).

Two consumption modes over the same state:

* **sync** (``for tok in engine.stream(rid)``): when the cursor catches
  up with the emitted output, ``__next__`` drives ``engine.step()``
  itself until the next token lands — a self-clocking drain loop that
  interleaves every other resident request's progress.
* **async** (``async for tok in engine.stream(rid)``): ``__anext__``
  parks on a per-stream ``asyncio.Event`` that the engine sets after
  every tick and on every terminal transition. Something else — the
  asyncio front-end in ``launch/serve.py`` — must be ticking the
  engine; the stream itself never steps, so arrival, compute and
  delivery overlap on one event loop.

Terminal states are explicit so consumers never hang: ``finish`` (normal
completion -> StopIteration), ``cancel`` (``engine.cancel(rid)`` ->
``StreamCancelled``), ``fail`` (``run(strict=True)`` died undrained ->
``StreamError`` carrying the engine error).
"""
from __future__ import annotations

__all__ = ["StreamCancelled", "StreamError", "StreamState", "TokenStream"]

#: ticks a dry sync stream will drive without the request finishing or
#: emitting before giving up — the same runaway guard run(max_steps) has
_MAX_IDLE_STEPS = 10_000

LIVE = "live"
DONE = "done"
CANCELLED = "cancelled"
ERROR = "error"


class StreamCancelled(Exception):
    """The request behind this stream was cancelled mid-flight."""


class StreamError(Exception):
    """The engine died with this request still live (undrained strict
    run); ``__cause__`` carries the engine's error."""


class StreamState:
    """Engine-side delivery state for one submitted Request: a terminal
    status machine plus the asyncio wakeup fan-out. One per Request
    *object* — resubmitting a rid after cancellation binds a fresh
    state, and streams opened on the old one stay terminal."""

    __slots__ = ("req", "status", "error", "_events")

    def __init__(self, req):
        self.req = req
        self.status = LIVE
        self.error: BaseException | None = None
        self._events: list = []         # one asyncio.Event per waiter

    # -- terminal transitions (engine-side) -----------------------------------

    def finish(self):
        if self.status == LIVE:
            self.status = DONE
        self.notify()

    def cancel(self):
        if self.status == LIVE:
            self.status = CANCELLED
        self.notify()

    def fail(self, exc: BaseException):
        if self.status == LIVE:
            self.status = ERROR
            self.error = exc
        self.notify()

    def notify(self):
        """Wake every async waiter (the engine calls this once per tick;
        sync consumers poll and never register an event)."""
        for ev in self._events:
            ev.set()

    def register_event(self, ev):
        self._events.append(ev)

    def unregister_event(self, ev):
        if ev in self._events:
            self._events.remove(ev)


class TokenStream:
    """One consumer's view of a request's emitted tokens. Iteration
    yields every token exactly once in emission order; multiple streams
    over the same rid each see the full sequence (independent cursors
    over the same ``Request.output``)."""

    def __init__(self, engine, state: StreamState):
        self._engine = engine
        self._state = state
        self._cursor = 0

    @property
    def rid(self) -> int:
        return self._state.req.rid

    def _pop(self):
        """The next undelivered token, or None when the cursor is caught
        up with emission."""
        out = self._state.req.output
        if self._cursor < len(out):
            tok = int(out[self._cursor])
            self._cursor += 1
            return tok
        return None

    def _raise_terminal(self):
        st = self._state
        if st.status == CANCELLED:
            raise StreamCancelled(
                f"request {st.req.rid} was cancelled after "
                f"{len(st.req.output)} token(s)")
        if st.status == ERROR:
            raise StreamError(
                f"request {st.req.rid}: engine error with the request "
                f"still live") from st.error
        raise StopIteration                  # DONE

    # -- sync: the stream drives the engine -----------------------------------

    def __iter__(self):
        return self

    def __next__(self) -> int:
        for _ in range(_MAX_IDLE_STEPS):
            tok = self._pop()
            if tok is not None:
                return tok
            if self._state.status != LIVE:
                self._raise_terminal()
            self._engine.step()
        raise RuntimeError(
            f"stream for request {self._state.req.rid}: no token after "
            f"{_MAX_IDLE_STEPS} engine steps — the request cannot make "
            "progress (check pool capacity / scheduler state)")

    # -- async: something else ticks the engine -------------------------------

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        import asyncio
        ev = asyncio.Event()
        self._state.register_event(ev)
        try:
            while True:
                tok = self._pop()
                if tok is not None:
                    return tok
                if self._state.status != LIVE:
                    try:
                        self._raise_terminal()
                    except StopIteration:
                        raise StopAsyncIteration from None
                ev.clear()
                # re-check before parking: a tick may have landed tokens
                # (or a terminal transition) between _pop and clear
                if (self._cursor < len(self._state.req.output)
                        or self._state.status != LIVE):
                    continue
                await ev.wait()
        finally:
            self._state.unregister_event(ev)

    def poll(self) -> list[int]:
        """Every token emitted since the last poll, without blocking or
        driving the engine — the delivery loop for callers that tick the
        engine themselves (the streaming benchmark). Empty list when the
        cursor is caught up OR the stream is terminal; check
        ``finished`` to tell them apart."""
        out = []
        while True:
            tok = self._pop()
            if tok is None:
                return out
            out.append(tok)

    @property
    def finished(self) -> bool:
        """True once the stream can never yield another token."""
        return (self._state.status != LIVE
                and self._cursor >= len(self._state.req.output))

    def drain(self) -> list[int]:
        """Collect every remaining token synchronously (drives the
        engine). Convenience for tests and benchmarks."""
        return list(self)
