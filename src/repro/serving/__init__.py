from .config import ServeConfig
from .engine import Request, ServeEngine
from .kv_cache import (PagePool, StateCache, cross_kv_bytes_per_seq,
                       kv_bytes_per_token, pool_bytes,
                       ssm_state_bytes_per_seq)
from .router import ReplicaRouter
from .spec import PromptLookupDrafter
from .stream import StreamCancelled, StreamError, TokenStream

__all__ = ["Request", "ServeConfig", "ServeEngine", "ReplicaRouter",
           "PagePool", "StateCache",
           "kv_bytes_per_token", "pool_bytes", "ssm_state_bytes_per_seq",
           "cross_kv_bytes_per_seq", "PromptLookupDrafter",
           "TokenStream", "StreamCancelled", "StreamError"]
