from .engine import Request, ServeEngine
