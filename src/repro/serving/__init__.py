from .engine import Request, ServeEngine
from .kv_cache import PagePool, kv_bytes_per_token, pool_bytes
from .spec import PromptLookupDrafter

__all__ = ["Request", "ServeEngine", "PagePool", "kv_bytes_per_token",
           "pool_bytes", "PromptLookupDrafter"]
