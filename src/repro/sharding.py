"""Per-arch sharding policy (DESIGN.md §4).

Everything here produces PartitionSpec pytrees matching the params / batch /
cache structures. Rules are path-aware (Megatron TP alternation: column-
parallel QKV/up/gate, row-parallel O/down → one all-reduce per block) and
divisibility-aware (jit inputs must shard evenly; intermediates may pad).

FSDP (ZeRO-3) additionally shards a second weight dim over the data axis —
required to fit the 1T-param configs; XLA all-gathers per scanned layer and
reduce-scatters gradients.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = ["ShardingPolicy", "make_policy"]

#: params whose name picks row-parallel (shard input dim over model)
_ROW_PARALLEL = {"wo", "down", "down_proj", "out_proj"}
#: column-parallel (shard output dim over model)
_COL_PARALLEL = {"wq", "wk", "wv", "up", "gate", "in_proj", "w_in", "x_proj",
                 "dt_proj", "w_gates", "head"}
#: replicated regardless of size
_REPLICATED = {"norm1", "norm2", "norm_x", "final_norm", "enc_norm", "router",
               "conv_w", "conv_b", "A_log", "D", "out_norm_g", "r", "b", "g"}
#: MoE stacked experts: expert dim shards over model (expert parallelism)
_EXPERT = {"moe"}


def _path_keys(path) -> list[str]:
    out = []
    for k in path:
        key = getattr(k, "key", None)
        if key is None:
            key = getattr(k, "name", None)
        if key is None and hasattr(k, "idx"):
            key = str(k.idx)
        out.append(str(key))
    return out


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


class ShardingPolicy:
    """parallelism:
      * "tp"   — Megatron TP over the model axis + DP(/FSDP) over data.
        Required for serving (latency) and for archs whose layer doesn't
        fit one chip (MoE giants).
      * "fsdp" — pure ZeRO-3: batch shards over EVERY axis (incl. model),
        parameters fully shard and are all-gathered per layer; there are NO
        activation collectives. For <=30B trains at global_batch >= chips
        this cuts per-layer collective bytes ~12x vs tp (EXPERIMENTS.md
        §Perf iter 6) — per-layer param gathers are small next to SP
        activation gathers at 4k-token/chip batches.
    """

    def __init__(self, cfg: ArchConfig, mesh: Mesh, *, fsdp: bool = False,
                 parallelism: str = "tp", quantized_serving: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.fsdp = fsdp or parallelism == "fsdp"
        self.parallelism = parallelism
        axes = dict(mesh.shape)
        if parallelism == "replicated":
            # CP serving: quantized weights are small enough to live on
            # every chip — zero weight collectives, and token-sharded
            # activations never conflict with weight shardings (GSPMD's
            # "involuntary full rematerialization" cascade, §Perf cell 2)
            self.model_axis = "model"
            self.n_model = 1
            self.data_axes = tuple(a for a in ("pod", "data") if a in axes)
            self.fsdp_axes = ()
            self.fsdp = False
        elif parallelism == "fsdp":
            self.model_axis = None
            self.n_model = 1
            self.data_axes = tuple(a for a in ("pod", "data", "model")
                                   if a in axes)
            self.fsdp_axes = tuple(a for a in ("data", "model") if a in axes)
        else:
            self.model_axis = "model"
            self.n_model = axes["model"]
            self.data_axes = tuple(a for a in ("pod", "data") if a in axes)
            # FSDP normally shards over 'data' only (params never cross
            # DCN); the 398B/1T giants don't fit one pod's HBM, so for them
            # ZeRO-3 extends over the pod axis too (per-layer all-gathers
            # cross DCN — the documented cost of fitting 1T params at all)
            giant = cfg.param_count_estimate() > 100e9
            self.fsdp_axes = (self.data_axes if ("pod" in axes and giant)
                              else tuple(a for a in ("data",) if a in axes))
        self.n_data = int(np.prod([axes[a] for a in self.data_axes]))
        self.n_fsdp = int(np.prod([axes[a] for a in self.fsdp_axes])) or 1

    # -- leaf rules ---------------------------------------------------------

    def _weight_spec(self, keys: list[str], shape: tuple) -> P:
        """Spec for one array leaf of the params pytree. Stacked leading
        period/expert dims are detected by path context."""
        nd = len(shape)
        name_hits = set(keys)
        # embedding table
        if "table" in name_hits:
            v, d = shape[-2], shape[-1]
            fx = (self.fsdp_axes if len(self.fsdp_axes) > 1
                  else (self.fsdp_axes[0] if self.fsdp_axes else None))
            if self.n_model > 1 and _div(v, self.n_model):
                spec = [None] * (nd - 2) + [self.model_axis, None]
            elif self.n_model > 1 and _div(d, self.n_model):
                spec = [None] * (nd - 2) + [None, self.model_axis]
            elif self.fsdp and _div(v, self.n_fsdp):
                spec = [None] * (nd - 2) + [fx, None]
            elif self.fsdp and _div(d, self.n_fsdp):
                spec = [None] * (nd - 2) + [None, fx]
            else:
                spec = [None] * nd
            return P(*spec)
        if "router" in name_hits:
            return P(*([None] * nd))    # tiny; shard_map expects replicated
        if name_hits & _REPLICATED and not (name_hits & {"moe"}):
            return P(*([None] * nd))
        if nd < 2:
            return P(*([None] * nd))

        spec: list = [None] * nd
        # MoE experts: (P?, E, D, F) — expert dim over model
        if name_hits & _EXPERT and nd >= 3 \
                and not (name_hits & _REPLICATED):
            # find the expert dim: first dim equal to n_experts
            for i, s in enumerate(shape):
                if s == self.cfg.n_experts and _div(s, self.n_model):
                    spec[i] = self.model_axis
                    break
            else:
                return self._tp_spec(keys, shape)
            if self.fsdp:
                # shard d_ff (largest remaining divisible dim) over the
                # fsdp axes
                cands = [(s, i) for i, s in enumerate(shape)
                         if spec[i] is None and _div(s, self.n_fsdp)]
                if cands:
                    _, i = max(cands)
                    spec[i] = (self.fsdp_axes if len(self.fsdp_axes) > 1
                               else self.fsdp_axes[0])
            return P(*spec)
        return self._tp_spec(keys, shape)

    def _tp_spec(self, keys: list[str], shape: tuple) -> P:
        nd = len(shape)
        name_hits = set(keys)
        spec: list = [None] * nd
        # pick TP dim: row-parallel -> -2, column-parallel -> -1, else largest
        tp_dim = None
        if name_hits & _ROW_PARALLEL and nd >= 2:
            tp_dim = nd - 2
        elif name_hits & _COL_PARALLEL:
            tp_dim = nd - 1
        if tp_dim is not None and not _div(shape[tp_dim], self.n_model):
            tp_dim = None
        if tp_dim is None:
            cands = [(s, i) for i, s in enumerate(shape[-2:], start=nd - 2)
                     if _div(s, self.n_model)]
            if cands:
                _, tp_dim = max(cands)
        if tp_dim is not None:
            spec[tp_dim] = self.model_axis
        if self.fsdp and nd >= 2 and self.fsdp_axes:
            cands = [(s, i) for i, s in enumerate(shape)
                     if spec[i] is None and i >= nd - 2
                     and _div(s, self.n_fsdp)]
            if cands:
                _, i = max(cands)
                spec[i] = (self.fsdp_axes if len(self.fsdp_axes) > 1
                           else self.fsdp_axes[0])
        return P(*spec)

    # -- pytree walkers ------------------------------------------------------

    def param_specs(self, params_shape: Any):
        """PartitionSpec pytree mirroring `params_shape` (ShapeDtypeStructs
        or arrays; QuantizedTensors descend to codes/scale leaves)."""
        def leaf(path, x):
            if self.parallelism == "replicated":
                return P(*([None] * len(x.shape)))
            keys = _path_keys(path)
            spec = self._weight_spec(keys, tuple(x.shape))
            # quantized codes on a packed dim: the packed (last) dim is N/2 —
            # divisibility already checked against the code shape itself.
            return spec
        return jax.tree_util.tree_map_with_path(leaf, params_shape)

    def cache_specs(self, caches_shape: Any):
        dp = self.data_axes

        def leaf(path, x):
            keys = _path_keys(path)
            shape = tuple(x.shape)
            nd = len(shape)
            kv_key = keys[-1] in ("k", "v", "xk", "xv") or (
                len(keys) >= 2 and keys[-2] in ("k", "v")
                and keys[-1] in ("codes", "scale"))
            if kv_key:
                # (P, B, Hkv, S, dh|1): seq over model (flash-decode CP);
                # int8-quantized caches have codes+scale leaves
                spec = [None, dp, None, self.model_axis, None]
                if not _div(shape[3], self.n_model):
                    spec[3] = None
                if not _div(shape[1], self.n_data):
                    spec[1] = self._batch_axes(shape[1])
                return P(*spec)
            # ssm states: (P, B, ...): batch over data; largest divisible
            # trailing dim over model
            spec = [None] * nd
            spec[1] = self._batch_axes(shape[1])
            cands = [(s, i) for i, s in enumerate(shape[2:], start=2)
                     if _div(s, self.n_model)]
            if cands:
                _, i = max(cands)
                spec[i] = self.model_axis
            return P(*spec)

        return jax.tree_util.tree_map_with_path(leaf, caches_shape)

    def paged_state_specs(self, caches: Any):
        """Specs for the serving engine's paged StateCache pytree.

        Token-KV page pools (``kp``/``vp``, ``(P, n_pages, Hkv, page_size,
        dh)``) and read-only cross entries (``xk``/``xv``, ``(P, n_cross,
        Hkv, S_enc, dh)``) shard their KV-head axis over the model axis
        when it divides — including the ``codes``/``scale`` children of a
        quantized pool, which share the head axis. Everything else
        (recurrent slabs, conv states) is per-sequence with no head axis
        and replicates. Block tables and write cursors live host-side and
        never enter this tree."""
        pool_keys = ("kp", "vp", "xk", "xv")

        def leaf(path, x):
            keys = _path_keys(path)
            shape = tuple(x.shape)
            nd = len(shape)
            kv_key = keys[-1] in pool_keys or (
                len(keys) >= 2 and keys[-2] in pool_keys
                and keys[-1] in ("codes", "scale"))
            if kv_key and nd == 5 and self.n_model > 1 \
                    and _div(shape[2], self.n_model):
                return P(None, None, self.model_axis, None, None)
            return P(*([None] * nd))

        return jax.tree_util.tree_map_with_path(leaf, caches)

    def _batch_axes(self, b: int):
        """Largest prefix of data axes that divides the batch."""
        axes = []
        rem = b
        for a in self.data_axes:
            n = dict(self.mesh.shape)[a]
            if rem % n == 0:
                axes.append(a)
                rem //= n
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    def batch_spec(self, b: int, extra_dims: int = 1):
        return P(self._batch_axes(b), *([None] * extra_dims))

    def opt_specs(self, params_shape: Any, opt_shape: Any):
        """Optimizer state mirrors param specs leaf-for-leaf where shapes
        match; scalars replicate."""
        pspecs = self.param_specs(params_shape)

        def match(ps, os_leaf_shape):
            return ps

        # momenta trees share param structure; walk both together
        def leaf(path, x):
            keys = _path_keys(path)
            if len(x.shape) == 0:
                return P()
            return self._weight_spec([k for k in keys if k not in
                                      ("mu", "nu", "m", "v", "ef")],
                                     tuple(x.shape))
        return jax.tree_util.tree_map_with_path(leaf, opt_shape)

    def named(self, spec_tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda s: isinstance(s, P))


def make_policy(cfg: ArchConfig, mesh: Mesh, **kw) -> ShardingPolicy:
    if kw.get("parallelism") == "fsdp":
        return ShardingPolicy(cfg, mesh, **kw)
    if "fsdp" not in kw:
        # FSDP (ZeRO-3) by default above 2B params: per-layer all-gathers
        # overlap with compute under the latency-hiding scheduler, and the
        # 16x reduction in resident params/optimizer is what fits the 8-15B
        # dense configs (and is mandatory for the 398B/1T giants)
        kw["fsdp"] = cfg.param_count_estimate() > 2e9
    return ShardingPolicy(cfg, mesh, **kw)
