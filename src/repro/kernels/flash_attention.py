"""Pallas TPU kernel: online-softmax (flash) attention for prefill.

Attention is the other hot matmul pair in every assigned transformer; the
same §3.1 pipelining story applies: K/V tiles stream HBM->VMEM while the MXU
works on the current block, and the softmax statistics (running max m,
running denominator l) live in VMEM scratch — the paper's `array t` again.

Grid: (B*H, Sq/bq, Skv/bkv), KV innermost. Causal masking prunes nothing
structurally (blocks are still visited) but masks within the tile; the ops.py
wrapper carries the exact sub-quadratic chunked reference used on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import pallas_compiler_params

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bkv: int, n_kv: int,
            out_dtype):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (bq, dh)
    k = k_ref[0]                       # (bkv, dh)
    v = v_ref[0]                       # (bkv, dh)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(q_pos >= k_pos, s, _NEG_INF)

    m_prev = m_ref[...]                # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)             # (bq, bkv)
    corr = jnp.exp(m_prev - m_new)     # (bq, 1)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        # rows with no unmasked key (can't happen for causal qpos>=0) guard
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bkv", "out_dtype", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           bq: int, bkv: int,
                           out_dtype=None, interpret: bool = False):
    """q: (BH, Sq, dh); k, v: (BH, Skv, dh) — heads pre-flattened into the
    leading dim (GQA expansion handled by the wrapper). Block shapes come
    from the planner (repro.runtime.planner). Returns (BH, Sq, dh).
    """
    bh, sq, dh = q.shape
    _, skv, _ = k.shape
    out_dtype = out_dtype or q.dtype
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    n_kv = skv // bkv
    scale = 1.0 / (dh ** 0.5)

    grid = (bh, sq // bq, n_kv)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq,
                          bkv=bkv, n_kv=n_kv, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
