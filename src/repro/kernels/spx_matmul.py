"""Pallas TPU kernel: pipelined SPx-quantized matmul (the paper's §3.1+§3.2).

This is the paper's accelerator, re-thought for the TPU memory hierarchy:

  FPGA                         TPU (this kernel)
  ----                         -----------------
  RAM -> input buffer          HBM -> VMEM tiles, double-buffered by the
  (clk_inbuff)                 Mosaic pipeline across grid steps
  PU pipeline (clk_compute)    MXU consuming the current VMEM tile while the
                               next tile's DMA is in flight
  row-of-weights per clock     (bk x bn) weight-code tile per grid step
  shift-add of PoT terms       b-bit code -> bf16 via VMEM LUT gather (VPU),
                               then a dense MXU matmul
  temporary `array t`          f32 accumulator tile in VMEM scratch

The load/compute decoupling argument of §3.1 (loading must stay ahead of
compute) is exactly the Pallas pipelining condition; quantized weight tiles
shrink t_load by 16/b versus bf16, which is what makes the pipeline
compute-bound for realistic (bm, bn, bk) — see core/pipeline.py for the
analytical check and the benchmarks for numbers.

Grid layout: (M/bm, N/bn, K/bk), K innermost; the output BlockSpec ignores
the K index so the same (bm, bn) accumulator tile is revisited across the
K loop (standard Pallas accumulation idiom).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import pallas_compiler_params

__all__ = ["spx_matmul_pallas"]


def _unpack_int4_block(codes):
    """(bk, bn/2) uint8 -> (bk, bn) uint8, even logical idx = low nibble."""
    lo = codes & 0x0F
    hi = (codes >> 4) & 0x0F
    stacked = jnp.stack([lo, hi], axis=-1)
    return stacked.reshape(codes.shape[0], codes.shape[1] * 2)


def _kernel(x_ref, codes_ref, scale_ref, lut_ref, o_ref, acc_ref, *,
            packed: bool, n_k: int, out_dtype):
    """One grid step: decode a weight tile in VMEM, MXU-accumulate."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = codes_ref[...]
    if packed:
        codes = _unpack_int4_block(codes)
    # LUT decode on the VPU: codes index a <=256-entry table resident in VMEM.
    w = jnp.take(lut_ref[...], codes.astype(jnp.int32), axis=0)
    x = x_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, w.astype(x.dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _finalize():
        # per-output-channel alpha applied once, after accumulation
        o_ref[...] = (acc_ref[...] * scale_ref[...]).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("packed", "bm", "bn", "bk", "out_dtype", "interpret"))
def spx_matmul_pallas(x, codes, scale, lut, *, packed: bool,
                      bm: int, bn: int, bk: int, out_dtype=None,
                      interpret: bool = False):
    """x:(M,K) @ dequant(codes:(K,N), scale:(1,N), lut:(2^b,)) -> (M,N).

    codes are uint8; if ``packed`` the stored array is (K, N//2) with two
    4-bit codes per byte. Block shapes are chosen by the planner
    (repro.runtime.planner) and passed explicitly; shapes must be pre-padded
    to block multiples by the ops.py wrapper.
    """
    m, k = x.shape
    n = scale.shape[-1]
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    pack_div = 2 if packed else 1

    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, packed=packed, n_k=n_k,
                          out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn // pack_div), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec(lut.shape, lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, codes, scale, lut)
