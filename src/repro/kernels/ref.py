"""Pure-jnp oracles for every Pallas kernel. These are the source of truth
for correctness tests (interpret-mode kernels must allclose against these)
and the implementation used on non-TPU backends and in the 512-device
dry-run (mathematically identical; XLA:TPU would fuse the dequant into the
matmul the same way the kernel does by hand)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import spx

__all__ = ["spx_matmul_ref", "attention_ref", "paged_attention_ref",
           "paged_attention_quant_ref", "paged_decode_ragged_ref",
           "paged_decode_ragged_quant_ref"]


def spx_matmul_ref(x, codes, scale, lut, *, packed: bool, out_dtype=None):
    """x:(..., K) @ (lut[codes:(K,N)] * scale:(1,N)) -> (..., N).
    Contracts x's LAST dim without flattening leading dims (their sharding
    must survive — see ops.spx_matmul)."""
    out_dtype = out_dtype or x.dtype
    if packed:
        codes = spx.unpack_int4(codes)
    w = jnp.take(lut, codes.astype(jnp.int32), axis=0)   # (K, N) in lut dtype
    acc = jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return (acc * scale).astype(out_dtype)


def paged_attention_ref(q, k_pages, v_pages, block_table, ctx_len, *,
                        out_dtype=None):
    """Single-token decode attention over a paged KV cache.

    q: (B, Hkv, rep, dh) — query heads grouped under their KV head;
    k_pages/v_pages: (n_pages, Hkv, page_size, dh) physical page pools;
    block_table: (B, max_pages) int32 physical page per logical page;
    ctx_len: (B,) int32 — tokens attendable (positions < ctx_len).
    Returns (B, Hkv, rep, dh).

    Gathers this sequence's pages into a contiguous view and runs a plain
    max-shifted softmax in f32 — the oracle the Pallas kernel's online
    softmax must match.
    """
    out_dtype = out_dtype or q.dtype
    b, hkv, rep, dh = q.shape
    ps = k_pages.shape[2]
    max_pages = block_table.shape[1]
    s_max = max_pages * ps
    # gather: (B, max_pages, Hkv, ps, dh) -> (B, Hkv, S, dh)
    k = jnp.moveaxis(k_pages[block_table], 2, 1).reshape(b, hkv, s_max, dh)
    v = jnp.moveaxis(v_pages[block_table], 2, 1).reshape(b, hkv, s_max, dh)
    return _paged_softmax(q, k, v, ctx_len, out_dtype)


def _paged_softmax(q, k, v, ctx_len, out_dtype):
    """Shared masked-softmax core of the paged oracles. q: (B,Hkv,rep,dh);
    k/v: (B,Hkv,S,dh) contiguous gathered views."""
    dh = q.shape[-1]
    s_max = k.shape[2]
    s = jnp.einsum("bhrd,bhkd->bhrk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    pos = jnp.arange(s_max)
    s = jnp.where(pos[None, None, None, :] < ctx_len[:, None, None, None],
                  s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhrk,bhkd->bhrd", p, v.astype(jnp.float32))
    o = o / jnp.maximum(l, 1e-30)
    # ctx == 0 rows (inactive slots): everything is masked and the shifted
    # softmax degenerates to a mean — force the kernel's all-zero output
    o = jnp.where(ctx_len[:, None, None, None] > 0, o, 0.0)
    return o.astype(out_dtype)


def paged_attention_quant_ref(q, k_codes, k_scale, v_codes, v_scale,
                              block_table, ctx_len, lut, *, out_dtype=None):
    """Quantized-pool variant of ``paged_attention_ref``: pools hold uint8
    codebook codes plus a per-token f32 scale, and dequantization
    (``lut[codes] * scale``) is fused after the page gather — the oracle
    the fused-dequant Pallas kernel must match.

    k_codes/v_codes: (n_pages, Hkv, page_size, dh) uint8; k_scale/v_scale:
    (n_pages, Hkv, page_size, 1) f32; lut: (2^w,) f32 codebook
    (spx.codebook of the KV scheme). Other args as paged_attention_ref.
    """
    out_dtype = out_dtype or q.dtype
    b, hkv, rep, dh = q.shape
    ps = k_codes.shape[2]
    s_max = block_table.shape[1] * ps

    def gather_dequant(codes, scale):
        c = jnp.moveaxis(codes[block_table], 2, 1).reshape(b, hkv, s_max, dh)
        a = jnp.moveaxis(scale[block_table], 2, 1).reshape(b, hkv, s_max, 1)
        return jnp.take(lut, c.astype(jnp.int32), axis=0) * a

    k = gather_dequant(k_codes, k_scale)
    v = gather_dequant(v_codes, v_scale)
    return _paged_softmax(q, k, v, ctx_len, out_dtype)


def _ragged_softmax(q, k, v, ctx_len, q_len, w: int, out_dtype):
    """Shared masked-softmax core of the ragged decode-window oracles.

    q: (B, Hkv, R, dh) with R = rep * w rows ordered rep-major — row
    ``r * w + i`` is window position ``i`` of the ``r``-th query head
    sharing this KV head; k/v: (B, Hkv, S, dh) contiguous gathered views.
    Window position ``i`` attends positions <= ctx_len + i (absolute
    causality inside the window); rows at positions >= q_len are padding
    and come back exactly zero.
    """
    dh = q.shape[-1]
    r_rows = q.shape[2]
    s_max = k.shape[2]
    s = jnp.einsum("bhrd,bhkd->bhrk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    win = jnp.arange(r_rows) % w                          # (R,)
    pos = jnp.arange(s_max)
    row_ok = win[None, None, :, None] < q_len[:, None, None, None]
    mask = (pos[None, None, None, :]
            <= ctx_len[:, None, None, None] + win[None, None, :, None]) \
        & row_ok
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhrk,bhkd->bhrd", p, v.astype(jnp.float32))
    o = o / jnp.maximum(l, 1e-30)
    # fully-masked rows (window padding, inactive slots) degenerate to a
    # mean under the shifted softmax — force the kernel's all-zero output
    o = jnp.where(row_ok, o, 0.0)
    return o.astype(out_dtype)


def paged_decode_ragged_ref(q, k_pages, v_pages, block_table, ctx_len,
                            q_len, *, w: int, out_dtype=None):
    """Ragged decode-window attention over a paged KV cache — the oracle
    for the decode megakernel (one launch covers plain decode *and* the
    spec-decode verify window).

    q: (B, Hkv, R, dh), R = rep * w query rows per KV head, rep-major (row
    ``r * w + i`` = window position i of query head r); ``w`` is the
    static window length (spec K+1, or 1 for plain decode); q_len: (B,)
    int32 valid window rows per slot (ragged — rows past it return zero);
    ctx_len: (B,) int32 tokens in the pages *before* this window (window
    position i attends positions <= ctx_len + i). k_pages/v_pages/
    block_table as in ``paged_attention_ref``. Returns (B, Hkv, R, dh).
    """
    out_dtype = out_dtype or q.dtype
    b, hkv, _, dh = q.shape
    ps = k_pages.shape[2]
    s_max = block_table.shape[1] * ps
    k = jnp.moveaxis(k_pages[block_table], 2, 1).reshape(b, hkv, s_max, dh)
    v = jnp.moveaxis(v_pages[block_table], 2, 1).reshape(b, hkv, s_max, dh)
    return _ragged_softmax(q, k, v, ctx_len, q_len, w, out_dtype)


def paged_decode_ragged_quant_ref(q, k_codes, k_scale, v_codes, v_scale,
                                  block_table, ctx_len, q_len, lut, *,
                                  w: int, out_dtype=None):
    """Quantized-pool variant of ``paged_decode_ragged_ref``: pools hold
    uint8 codebook codes + per-token f32 scale, dequantized after the page
    gather (``lut[codes] * scale``) — the oracle the fused-LUT megakernel
    must match. Args as ``paged_attention_quant_ref`` plus q_len/w."""
    out_dtype = out_dtype or q.dtype
    b, hkv, _, dh = q.shape
    ps = k_codes.shape[2]
    s_max = block_table.shape[1] * ps

    def gather_dequant(codes, scale):
        c = jnp.moveaxis(codes[block_table], 2, 1).reshape(b, hkv, s_max, dh)
        a = jnp.moveaxis(scale[block_table], 2, 1).reshape(b, hkv, s_max, 1)
        return jnp.take(lut, c.astype(jnp.int32), axis=0) * a

    k = gather_dequant(k_codes, k_scale)
    v = gather_dequant(v_codes, v_scale)
    return _ragged_softmax(q, k, v, ctx_len, q_len, w, out_dtype)


def attention_ref(q, k, v, *, causal: bool = True, out_dtype=None):
    """Naive softmax attention. q:(BH,Sq,dh), k/v:(BH,Skv,dh)."""
    out_dtype = out_dtype or q.dtype
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (dh ** 0.5)
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        mask = (jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :])
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(out_dtype)
