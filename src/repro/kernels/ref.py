"""Pure-jnp oracles for every Pallas kernel. These are the source of truth
for correctness tests (interpret-mode kernels must allclose against these)
and the implementation used on non-TPU backends and in the 512-device
dry-run (mathematically identical; XLA:TPU would fuse the dequant into the
matmul the same way the kernel does by hand)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import spx

__all__ = ["spx_matmul_ref", "attention_ref"]


def spx_matmul_ref(x, codes, scale, lut, *, packed: bool, out_dtype=None):
    """x:(..., K) @ (lut[codes:(K,N)] * scale:(1,N)) -> (..., N).
    Contracts x's LAST dim without flattening leading dims (their sharding
    must survive — see ops.spx_matmul)."""
    out_dtype = out_dtype or x.dtype
    if packed:
        codes = spx.unpack_int4(codes)
    w = jnp.take(lut, codes.astype(jnp.int32), axis=0)   # (K, N) in lut dtype
    acc = jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return (acc * scale).astype(out_dtype)


def attention_ref(q, k, v, *, causal: bool = True, out_dtype=None):
    """Naive softmax attention. q:(BH,Sq,dh), k/v:(BH,Skv,dh)."""
    out_dtype = out_dtype or q.dtype
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (dh ** 0.5)
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        mask = (jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :])
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(out_dtype)
