"""jit'd public wrappers around the Pallas kernels with impl dispatch.

``impl`` semantics everywhere:
  * "auto"      — pallas on TPU, ref elsewhere (CPU CI, 512-dev dry-run)
  * "pallas"    — compiled Mosaic kernel (TPU target)
  * "interpret" — pallas_call(interpret=True): kernel body executed in
                  Python/XLA on CPU; used by tests to validate the kernel
                  logic bit-for-bit against the ref oracle
  * "ref"       — pure-jnp oracle
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spx
from repro.core.quantized import QuantizedTensor

from . import ref as ref_impl
from .flash_attention import DEFAULT_BKV, DEFAULT_BQ, flash_attention_pallas
from .spx_matmul import DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, spx_matmul_pallas

__all__ = ["spx_matmul", "flash_attention", "resolve_impl"]

_BLOCK_CANDIDATES = (512, 384, 256, 128, 64, 32, 16, 8)


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _divisor_block(dim: int, preferred: int) -> int | None:
    if dim % preferred == 0:
        return preferred
    for c in _BLOCK_CANDIDATES:
        if c <= dim and dim % c == 0:
            return c
    return None


def spx_matmul(x: jax.Array, qt: QuantizedTensor, *, impl: str = "auto",
               bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
               bk: int = DEFAULT_BK, out_dtype=None) -> jax.Array:
    """x: (..., K) @ dequant(qt: (K, N)) -> (..., N)."""
    impl = resolve_impl(impl)
    k_dim, n_dim = qt.logical_shape
    lut = qt.lut
    scale = qt.scale.reshape(1, n_dim).astype(jnp.float32)

    if impl == "ref":
        # NO reshape: dot_general contracts x's last dim directly, so a
        # (batch@data, seq@model, K) sharding survives — flattening to 2-D
        # merges differently-sharded dims and forces a full gather
        # (measured 16x replicated linear-layer compute, §Perf cell 2)
        return ref_impl.spx_matmul_ref(x, qt.codes, scale, lut,
                                       packed=qt.packed, out_dtype=out_dtype)

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]

    bn_eff = _divisor_block(n_dim, bn)
    bk_eff = _divisor_block(k_dim, bk)
    if qt.packed and bn_eff is not None and bn_eff % 2:
        bn_eff = None
    if bn_eff is None or bk_eff is None:   # ragged dims: oracle fallback
        out = ref_impl.spx_matmul_ref(x2, qt.codes, scale, lut,
                                      packed=qt.packed, out_dtype=out_dtype)
        return out.reshape(*lead, n_dim)

    bm_eff = min(bm, m)
    pad_m = (-m) % bm_eff
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    out = spx_matmul_pallas(
        x2, qt.codes, scale, lut, packed=qt.packed,
        bm=bm_eff, bn=bn_eff, bk=bk_eff, out_dtype=out_dtype,
        interpret=(impl == "interpret"))
    if pad_m:
        out = out[:m]
    return out.reshape(*lead, n_dim)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, impl: str = "auto",
                    bq: int = DEFAULT_BQ, bkv: int = DEFAULT_BKV) -> jax.Array:
    """GQA attention. q: (B, Hq, Sq, dh); k, v: (B, Hkv, Skv, dh);
    Hq % Hkv == 0. Returns (B, Hq, Sq, dh)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    impl = resolve_impl(impl)

    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * hq, sq, dh)
    kf = k.reshape(b * hq, skv, dh)
    vf = v.reshape(b * hq, skv, dh)

    if impl == "ref":
        return ref_impl.attention_ref(qf, kf, vf, causal=causal).reshape(q.shape)

    bq_eff = _divisor_block(sq, bq)
    bkv_eff = _divisor_block(skv, bkv)
    if bq_eff is None or bkv_eff is None:
        return ref_impl.attention_ref(qf, kf, vf, causal=causal).reshape(q.shape)
    out = flash_attention_pallas(qf, kf, vf, causal=causal, bq=bq_eff,
                                 bkv=bkv_eff, interpret=(impl == "interpret"))
    return out.reshape(q.shape)
