"""Public quantized-matmul / attention entry points, dispatched through the
execution-plan runtime (DESIGN.md §7).

Every impl of each op registers itself in ``repro.runtime.registry`` with an
availability predicate; ``spx_matmul`` / ``flash_attention`` resolve the
impl once (cached per backend) and fetch block shapes from
``repro.runtime.planner`` — the per-shape analytical solution of the
paper's §3.1 load-vs-compute inequality — instead of the old hard-coded
one-size-fits-all tiles and per-callsite string matching.

``impl`` semantics (see registry docstring): auto | pallas | interpret | ref.
"""
from __future__ import annotations

import collections
import functools
import time

import jax
import jax.numpy as jnp

from repro.core import spx
from repro.core.quantized import QuantizedTensor
from repro.runtime import planner, registry

from . import ref as ref_impl
from .flash_attention import flash_attention_pallas
from .paged_attention import (paged_attention_pallas,
                              paged_attention_quant_pallas,
                              paged_decode_ragged_pallas,
                              paged_decode_ragged_quant_pallas)
from .spx_matmul import spx_matmul_pallas

__all__ = ["spx_matmul", "flash_attention", "paged_attention",
           "paged_attention_quant", "paged_decode_ragged", "resolve_impl",
           "op_calls", "reset_op_calls"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Trace-time launch accounting: each public wrapper bumps its op counter on
# every call. Under jit the wrapper body runs at TRACE time only, so after a
# steady-state run the counter reads *kernel launches per compiled step* —
# the megakernel tests assert exactly one paged_decode_ragged per decode
# trace and zero legacy paged_attention* calls.
# ---------------------------------------------------------------------------

_OP_CALLS: collections.Counter = collections.Counter()


def op_calls() -> dict[str, int]:
    """Wrapper-call counts per op since the last ``reset_op_calls()``."""
    return dict(_OP_CALLS)


def reset_op_calls() -> None:
    _OP_CALLS.clear()


def resolve_impl(impl: str) -> str:
    """Deprecated shim (one PR): impl-name resolution now lives in
    repro.runtime.registry; kept for callers that only need the name."""
    return registry.resolve("spx_matmul", impl).impl


# ---------------------------------------------------------------------------
# spx_matmul: x2 (M, K) @ dequant(qt (K, N)) — registered impls share the
# signature fn(x2, qt, scale, *, plan, out_dtype, ...)
# ---------------------------------------------------------------------------

@registry.register("spx_matmul", "ref",
                   priority=registry.PRIORITY_REFERENCE)
def _spx_matmul_ref(x2, qt: QuantizedTensor, scale, *, plan, out_dtype):
    del plan
    return ref_impl.spx_matmul_ref(x2, qt.codes, scale, qt.lut,
                                   packed=qt.packed, out_dtype=out_dtype)


def _spx_matmul_planned(x2, qt: QuantizedTensor, scale, *, plan, out_dtype,
                        interpret: bool):
    m = x2.shape[0]
    bm_eff = min(plan.bm, m)
    pad_m = (-m) % bm_eff
    if pad_m:
        x2 = jnp.pad(x2, ((0, pad_m), (0, 0)))
    out = spx_matmul_pallas(
        x2, qt.codes, scale, qt.lut, packed=qt.packed,
        bm=bm_eff, bn=plan.bn, bk=plan.bk, out_dtype=out_dtype,
        interpret=interpret)
    return out[:m] if pad_m else out


registry.register("spx_matmul", "pallas",
                  priority=registry.PRIORITY_ACCELERATOR,
                  available=_on_tpu)(
    functools.partial(_spx_matmul_planned, interpret=False))
registry.register("spx_matmul", "interpret",
                  priority=registry.PRIORITY_DEBUG)(
    functools.partial(_spx_matmul_planned, interpret=True))


def spx_matmul(x: jax.Array, qt: QuantizedTensor, *, impl: str = "auto",
               out_dtype=None) -> jax.Array:
    """x: (..., K) @ dequant(qt: (K, N)) -> (..., N)."""
    _OP_CALLS["spx_matmul"] += 1
    entry = registry.resolve("spx_matmul", impl)
    k_dim, n_dim = qt.logical_shape
    scale = qt.scale.reshape(1, n_dim).astype(jnp.float32)

    if entry.impl == "ref":
        # NO reshape: dot_general contracts x's last dim directly, so a
        # (batch@data, seq@model, K) sharding survives — flattening to 2-D
        # merges differently-sharded dims and forces a full gather
        # (measured 16x replicated linear-layer compute, EXPERIMENTS.md
        # §Perf cell 2)
        return entry.fn(x, qt, scale, plan=None, out_dtype=out_dtype)

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    plan = planner.plan_matmul(m, k_dim, n_dim, weight_bits=qt.bits,
                               act_bytes=x.dtype.itemsize, packed=qt.packed)
    if plan is None:                       # ragged dims: oracle fallback
        out = ref_impl.spx_matmul_ref(x2, qt.codes, scale, qt.lut,
                                      packed=qt.packed, out_dtype=out_dtype)
        return out.reshape(*lead, n_dim)
    if entry.impl == "pallas" and planner.autotune_enabled():
        # dtype is part of the key: load time and VMEM fit depend on the
        # activation byte width, so an f32-tuned winner must not be reused
        # for a shape-identical bf16 call
        key = ("spx_matmul", m, k_dim, n_dim, qt.bits, qt.packed,
               x.dtype.itemsize)
        measured = planner.measured_plan(key)
        if measured is not None:
            # shape keys are concrete even at trace time, so a winner
            # measured during an eager warm-up applies inside jitted steps
            plan = measured
        elif not isinstance(x2, jax.core.Tracer):
            # measure on concrete arrays only: under an outer jit the
            # runner would time abstract tracing, not kernel execution,
            # and cache a garbage plan
            plan = _autotune_matmul(key, entry, x2, qt, scale, plan,
                                    out_dtype)
    out = entry.fn(x2, qt, scale, plan=plan, out_dtype=out_dtype)
    return out.reshape(*lead, n_dim)


def _autotune_matmul(key, entry, x2, qt, scale, plan, out_dtype):
    """Measured refinement over divisor-legal candidates near the
    analytical plan (env-gated; see planner.autotune_enabled)."""
    m = x2.shape[0]
    k_dim, n_dim = qt.logical_shape
    bm_c, bn_c, bk_c = planner.matmul_candidates(m, k_dim, n_dim,
                                                 packed=qt.packed)
    cands = [plan] + [
        planner.MatmulBlocks(bm, bn, bk, False, 0.0, 0)
        for bm in bm_c[:3] for bn in bn_c[:3] for bk in bk_c[:3]
        if (bm, bn, bk) != (plan.bm, plan.bn, plan.bk)]

    def runner(p):
        f = lambda: entry.fn(x2, qt, scale, plan=p, out_dtype=out_dtype)
        jax.block_until_ready(f())         # compile + warm
        t0 = time.perf_counter()
        jax.block_until_ready(f())
        return time.perf_counter() - t0

    return planner.measured_best(key, cands, runner)


# ---------------------------------------------------------------------------
# flash_attention: qf/kf/vf (B*H, S, dh) — registered impls share the
# signature fn(qf, kf, vf, *, causal, plan)
# ---------------------------------------------------------------------------

@registry.register("flash_attention", "ref",
                   priority=registry.PRIORITY_REFERENCE)
def _flash_attention_ref(qf, kf, vf, *, causal, plan):
    del plan
    return ref_impl.attention_ref(qf, kf, vf, causal=causal)


def _flash_attention_planned(qf, kf, vf, *, causal, plan, interpret: bool):
    return flash_attention_pallas(qf, kf, vf, causal=causal, bq=plan.bq,
                                  bkv=plan.bkv, interpret=interpret)


registry.register("flash_attention", "pallas",
                  priority=registry.PRIORITY_ACCELERATOR,
                  available=_on_tpu)(
    functools.partial(_flash_attention_planned, interpret=False))
registry.register("flash_attention", "interpret",
                  priority=registry.PRIORITY_DEBUG)(
    functools.partial(_flash_attention_planned, interpret=True))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, impl: str = "auto") -> jax.Array:
    """GQA attention. q: (B, Hq, Sq, dh); k, v: (B, Hkv, Skv, dh);
    Hq % Hkv == 0. Returns (B, Hq, Sq, dh)."""
    _OP_CALLS["flash_attention"] += 1
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    entry = registry.resolve("flash_attention", impl)

    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * hq, sq, dh)
    kf = k.reshape(b * hq, skv, dh)
    vf = v.reshape(b * hq, skv, dh)

    if entry.impl == "ref":
        return entry.fn(qf, kf, vf, causal=causal, plan=None).reshape(q.shape)

    plan = planner.plan_attention(sq, skv, dh, act_bytes=q.dtype.itemsize)
    if plan is None:                       # ragged seq dims: ref fallback
        return ref_impl.attention_ref(qf, kf, vf,
                                      causal=causal).reshape(q.shape)
    return entry.fn(qf, kf, vf, causal=causal, plan=plan).reshape(q.shape)


# ---------------------------------------------------------------------------
# paged_attention: single-token decode over the paged KV cache — registered
# impls share the signature fn(q4, k_pages, v_pages, block_table, ctx_len)
# with q4: (B, Hkv, rep, dh)
# ---------------------------------------------------------------------------

@registry.register("paged_attention", "ref",
                   priority=registry.PRIORITY_REFERENCE)
def _paged_attention_ref(q4, k_pages, v_pages, block_table, ctx_len):
    return ref_impl.paged_attention_ref(q4, k_pages, v_pages, block_table,
                                        ctx_len)


registry.register("paged_attention", "pallas",
                  priority=registry.PRIORITY_ACCELERATOR,
                  available=_on_tpu)(
    functools.partial(paged_attention_pallas, interpret=False))
registry.register("paged_attention", "interpret",
                  priority=registry.PRIORITY_DEBUG)(
    functools.partial(paged_attention_pallas, interpret=True))


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_table: jax.Array, ctx_len: jax.Array, *,
                    impl: str = "auto") -> jax.Array:
    """Decode attention of one query token per sequence against its paged
    KV context.

    q: (B, Hq, dh); k_pages/v_pages: (n_pages, Hkv, page_size, dh) —
    physical page pools shared by all sequences; block_table:
    (B, max_pages) int32 physical page per logical page; ctx_len: (B,)
    int32 — positions < ctx_len attended (0 = inactive row, output zeros).
    Returns (B, Hq, dh). Page geometry is chosen at pool-allocation time
    via planner.plan_kv_pages, not per call.
    """
    _OP_CALLS["paged_attention"] += 1
    b, hq, dh = q.shape
    hkv = k_pages.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    q4 = q.reshape(b, hkv, hq // hkv, dh)
    entry = registry.resolve("paged_attention", impl)
    out = entry.fn(q4, k_pages, v_pages,
                   jnp.asarray(block_table, jnp.int32),
                   jnp.asarray(ctx_len, jnp.int32))
    return out.reshape(b, hq, dh)


# ---------------------------------------------------------------------------
# paged_attention_quant: decode over quantized (codes + scale) KV pools with
# the codebook dequant fused into the page loop — registered impls share the
# signature fn(q4, k_codes, k_scale, v_codes, v_scale, block_table, ctx_len,
# lut)
# ---------------------------------------------------------------------------

@registry.register("paged_attention_quant", "ref",
                   priority=registry.PRIORITY_REFERENCE)
def _paged_attention_quant_ref(q4, k_codes, k_scale, v_codes, v_scale,
                               block_table, ctx_len, lut):
    return ref_impl.paged_attention_quant_ref(q4, k_codes, k_scale,
                                              v_codes, v_scale,
                                              block_table, ctx_len, lut)


registry.register("paged_attention_quant", "pallas",
                  priority=registry.PRIORITY_ACCELERATOR,
                  available=_on_tpu)(
    functools.partial(paged_attention_quant_pallas, interpret=False))
registry.register("paged_attention_quant", "interpret",
                  priority=registry.PRIORITY_DEBUG)(
    functools.partial(paged_attention_quant_pallas, interpret=True))


def paged_attention_quant(q: jax.Array, k_pages: dict, v_pages: dict,
                          block_table: jax.Array, ctx_len: jax.Array, *,
                          kv_scheme: str = "uniform8",
                          impl: str = "auto") -> jax.Array:
    """Decode attention of one query token per sequence against its
    quantized paged KV context, with LUT dequantization fused into the
    page-streaming loop (no full-pool dequant pass — the paper's §3.2
    codes stay 1 byte/element all the way to VMEM).

    q: (B, Hq, dh); k_pages/v_pages: {"codes": (n_pages, Hkv, page_size,
    dh) uint8, "scale": (n_pages, Hkv, page_size, 1) f32} — the pools
    ``nn.attention.paged_kv_write`` maintains; ``kv_scheme`` names the
    core/spx level set the codes were quantized under (static — resolves
    to a <=256-entry f32 codebook). Returns (B, Hq, dh).
    """
    _OP_CALLS["paged_attention_quant"] += 1
    b, hq, dh = q.shape
    hkv = k_pages["codes"].shape[1]
    assert hq % hkv == 0, (hq, hkv)
    q4 = q.reshape(b, hkv, hq // hkv, dh)
    lut = spx.codebook(spx.scheme_levels(kv_scheme), dtype=jnp.float32)
    entry = registry.resolve("paged_attention_quant", impl)
    out = entry.fn(q4, k_pages["codes"], k_pages["scale"],
                   v_pages["codes"], v_pages["scale"],
                   jnp.asarray(block_table, jnp.int32),
                   jnp.asarray(ctx_len, jnp.int32), lut)
    return out.reshape(b, hq, dh)


# ---------------------------------------------------------------------------
# paged_decode_ragged: the decode megakernel — one launch covers the whole
# batched decode tick, plain decode AND the spec-decode verify window, over
# a ragged (slot, attend_len) grid, for dense or quantized (fused-LUT) KV
# pools. Registered impls share the signatures
#   dense: fn(q4, k_pages, v_pages, block_table, ctx_len, q_len, *, w)
#   quant: fn(q4, k_codes, k_scale, v_codes, v_scale, block_table, ctx_len,
#             q_len, lut, *, w)
# with q4: (B, Hkv, rep * w, dh) rep-major window rows.
# ---------------------------------------------------------------------------

@registry.register("paged_decode_ragged", "ref",
                   priority=registry.PRIORITY_REFERENCE)
def _paged_decode_ragged_ref(q4, k_pages, v_pages, block_table, ctx_len,
                             q_len, *, w):
    return ref_impl.paged_decode_ragged_ref(q4, k_pages, v_pages,
                                            block_table, ctx_len, q_len,
                                            w=w)


registry.register("paged_decode_ragged", "pallas",
                  priority=registry.PRIORITY_ACCELERATOR,
                  available=_on_tpu)(
    functools.partial(paged_decode_ragged_pallas, interpret=False))
registry.register("paged_decode_ragged", "interpret",
                  priority=registry.PRIORITY_DEBUG)(
    functools.partial(paged_decode_ragged_pallas, interpret=True))


@registry.register("paged_decode_ragged_quant", "ref",
                   priority=registry.PRIORITY_REFERENCE)
def _paged_decode_ragged_quant_ref(q4, k_codes, k_scale, v_codes, v_scale,
                                   block_table, ctx_len, q_len, lut, *, w):
    return ref_impl.paged_decode_ragged_quant_ref(
        q4, k_codes, k_scale, v_codes, v_scale, block_table, ctx_len,
        q_len, lut, w=w)


registry.register("paged_decode_ragged_quant", "pallas",
                  priority=registry.PRIORITY_ACCELERATOR,
                  available=_on_tpu)(
    functools.partial(paged_decode_ragged_quant_pallas, interpret=False))
registry.register("paged_decode_ragged_quant", "interpret",
                  priority=registry.PRIORITY_DEBUG)(
    functools.partial(paged_decode_ragged_quant_pallas, interpret=True))


def paged_decode_ragged(q: jax.Array, k_pages, v_pages,
                        block_table: jax.Array, ctx_len: jax.Array,
                        q_len: jax.Array, *, kv_scheme: str | None = None,
                        impl: str = "auto") -> jax.Array:
    """Ragged decode-window attention in ONE kernel launch per tick.

    q: (B, W, Hq, dh) — W window positions per slot (spec K+1, or 1 for
    plain decode; static). q_len: (B,) int32 valid window rows per slot —
    the ragged part; rows at positions >= q_len return exact zeros.
    ctx_len: (B,) int32 tokens already in the pages before this window
    (window position i of slot b attends cache positions <= ctx_len[b] +
    i; ctx_len = q_len = 0 marks an inactive slot, which skips every
    page). k_pages/v_pages: either the dense (n_pages, Hkv, page_size,
    dh) pools or the quantized {"codes", "scale"} dicts from
    ``nn.attention.paged_kv_write`` — a dict pool routes to the fused-LUT
    variant, with ``kv_scheme`` naming the codebook (required then).
    Returns (B, W, Hq, dh).

    The per-slot attend_len = ctx_len + q_len drives the kernel's page
    loop trip count directly — no pow2 window padding, so varying
    attend_len across ticks never retraces. Block tables and both length
    vectors ride as scalar prefetch.
    """
    _OP_CALLS["paged_decode_ragged"] += 1
    quant = isinstance(k_pages, dict)
    b, w, hq, dh = q.shape
    hkv = (k_pages["codes"] if quant else k_pages).shape[1]
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    # (B, W, Hq, dh) -> (B, Hkv, rep * w, dh), rep-major: row r * w + i is
    # window position i of query head r under this KV head
    q4 = jnp.moveaxis(q.reshape(b, w, hkv, rep, dh), 1, 3) \
            .reshape(b, hkv, rep * w, dh)
    block_table = jnp.asarray(block_table, jnp.int32)
    ctx_len = jnp.asarray(ctx_len, jnp.int32)
    q_len = jnp.asarray(q_len, jnp.int32)

    op = "paged_decode_ragged_quant" if quant else "paged_decode_ragged"
    entry = registry.resolve(op, impl)
    if quant:
        if kv_scheme is None:
            raise ValueError("quantized KV pools need kv_scheme for the "
                             "in-kernel codebook")
        lut = spx.codebook(spx.scheme_levels(kv_scheme), dtype=jnp.float32)
        args = (q4, k_pages["codes"], k_pages["scale"], v_pages["codes"],
                v_pages["scale"], block_table, ctx_len, q_len, lut)
        page_size = k_pages["codes"].shape[2]
    else:
        args = (q4, k_pages, v_pages, block_table, ctx_len, q_len)
        page_size = k_pages.shape[2]

    if entry.impl == "pallas" and planner.autotune_enabled():
        # keyed per workload INCLUDING kv_scheme and the window w (spec
        # K+1): dense vs codes+scale pools and decode vs verify windows
        # share array shapes but not cost — winners must not collide
        key = planner.fused_decode_key(b, hkv, rep, w, dh, page_size,
                                       block_table.shape[1], kv_scheme)
        if planner.measured_plan(key) is None \
                and not isinstance(q4, jax.core.Tracer):
            plan = planner.plan_fused_decode(
                dh, rep=rep, w=w, page_size=page_size,
                act_bytes=q.dtype.itemsize, kv_scheme=kv_scheme)

            def runner(p):
                del p
                f = lambda: entry.fn(*args, w=w)
                jax.block_until_ready(f())     # compile + warm
                t0 = time.perf_counter()
                jax.block_until_ready(f())
                return time.perf_counter() - t0

            planner.measured_best(key, [plan], runner)

    out = entry.fn(*args, w=w)
    # inverse of the rep-major packing
    return jnp.moveaxis(out.reshape(b, hkv, rep, w, dh), 3, 1) \
              .reshape(b, w, hq, dh)
