"""Pallas TPU kernel: single-token decode attention over a paged KV cache.

Serving's decode step is the paper's §3.1 pipeline with a twist: the K/V
"matrix" is no longer contiguous — it is scattered across fixed-size pages
owned by the sequence (see serving/kv_cache.py). The block table is a
*scalar-prefetch* argument (pltpu.PrefetchScalarGridSpec), so the physical
page index is known to the DMA engine before the grid step runs: the
gather happens in the BlockSpec index_map, and the inner loop is the same
double-buffered stream-pages-while-MXU-works pipeline as flash attention —
one (K, V) page pair in flight per (sequence, KV head) while the current
page's QK^T/PV runs, with running (m, l) softmax statistics in VMEM
scratch.

Grid: (B, Hkv, max_pages), pages innermost. GQA is handled by blocking the
query as (rep, dh) per KV head — the ``rep`` query heads that share a KV
head ride in one block and reuse the streamed page. Pages past a
sequence's context length are skipped (pl.when), and positions beyond
``ctx_len`` inside the last page are masked; unused block-table slots
point at page 0, whose DMA is wasted but whose values are never read.

Page size comes from ``runtime.planner.plan_kv_pages`` — the same
VMEM-budget model the matmul tiles use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import pallas_compiler_params

__all__ = ["paged_attention_pallas", "paged_attention_quant_pallas",
           "paged_decode_ragged_pallas", "paged_decode_ragged_quant_pallas"]

_NEG_INF = -1e30


def _init_stats(m_ref, l_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _page_update(q, k, v, ctx, j, *, scale, page_size, m_ref, l_ref,
                 acc_ref, k_scale=None, v_scale=None):
    """One page's contribution to the running online softmax: QK^T on the
    current (rep, dh) query block, causal/context masking inside the page,
    and the (m, l, acc) rescale-and-accumulate.

    ``k_scale``/``v_scale`` ((1, page_size), quantized pools only) are the
    per-token dequant scales, folded OUT of the dh contraction — k/v then
    carry bare codebook levels and the fold costs page_size multiplies on
    the score/prob rows instead of page_size x dh on the values (same
    algebra as nn/attention.py::_local_flash_decode)."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if k_scale is not None:
        s = s * k_scale
    rep = q.shape[0]
    pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (rep, page_size), 1)
    s = jnp.where(pos < ctx, s, _NEG_INF)

    m_prev = m_ref[...]                # (rep, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)             # (rep, page_size)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = p if v_scale is None else p * v_scale
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pv.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _finalize_out(o_ref, m_ref, l_ref, acc_ref, out_dtype):
    # ctx == 0 rows (inactive slots) never ran a page: l == 0, out == 0
    denom = jnp.maximum(l_ref[...], 1e-30)
    o_ref[0, 0] = (acc_ref[...] / denom).astype(out_dtype)


def _kernel(bt_ref, ctx_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale: float, page_size: int, n_logical: int,
            out_dtype):
    del bt_ref                    # consumed by the BlockSpec index maps
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _init_stats(m_ref, l_ref, acc_ref)

    ctx = ctx_ref[b]

    @pl.when(j * page_size < ctx)
    def _page():
        _page_update(q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], ctx, j,
                     scale=scale, page_size=page_size, m_ref=m_ref,
                     l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(j == n_logical - 1)
    def _finalize():
        _finalize_out(o_ref, m_ref, l_ref, acc_ref, out_dtype)


def _quant_kernel(bt_ref, ctx_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                  lut_ref, o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                  page_size: int, n_logical: int, out_dtype):
    """Fused-dequant variant: K/V pages arrive as uint8 codebook codes plus
    a per-token f32 scale; the LUT gather (VPU) happens page-by-page in
    VMEM, so HBM only ever moves 1-byte codes — the §3.2 memory win
    applied to the decode hot path. The codebook (<=256 f32 entries) is
    resident in VMEM for the whole grid."""
    del bt_ref
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _init_stats(m_ref, l_ref, acc_ref)

    ctx = ctx_ref[b]

    @pl.when(j * page_size < ctx)
    def _page():
        lut = lut_ref[...]
        k = jnp.take(lut, kc_ref[0, 0].astype(jnp.int32), axis=0)
        v = jnp.take(lut, vc_ref[0, 0].astype(jnp.int32), axis=0)
        _page_update(q_ref[0, 0], k, v, ctx, j, scale=scale,
                     page_size=page_size, m_ref=m_ref, l_ref=l_ref,
                     acc_ref=acc_ref,
                     k_scale=ks_ref[0, 0][:, 0][None, :],
                     v_scale=vs_ref[0, 0][:, 0][None, :])

    @pl.when(j == n_logical - 1)
    def _finalize():
        _finalize_out(o_ref, m_ref, l_ref, acc_ref, out_dtype)


# ---------------------------------------------------------------------------
# Ragged decode megakernel: one launch per decode tick (plain decode AND the
# spec-decode K+1 verify window ride the same ragged (slot, attend_len) grid)
# ---------------------------------------------------------------------------

def _ragged_page_update(q, k, v, ctx, qn, j, *, scale, page_size, w,
                        m_ref, l_ref, acc_ref, k_scale=None, v_scale=None):
    """One page's contribution for a ragged decode *window*: the query
    block is (rep * w, dh) — w window rows per query head, rep-major —
    and the causal mask is per-row: window position ``i = row % w``
    attends positions <= ctx + i. Rows past ``qn`` (ragged window tails,
    inactive slots) are masked entirely and their probabilities zeroed,
    so l stays 0 and the finalize step emits exact zeros for them —
    matching the ref oracle bit-for-bit in interpret mode."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if k_scale is not None:
        s = s * k_scale
    rows = q.shape[0]
    win = jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0) % w
    pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (rows, page_size), 1)
    mask = (pos <= ctx + win) & (win < qn)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                # (rows, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # fully-masked rows keep m == _NEG_INF, where exp(s - m) would be 1 —
    # the explicit zeroing keeps their l/acc at exactly 0
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = p if v_scale is None else p * v_scale
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pv.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _ragged_kernel(bt_ref, ctx_ref, qn_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, page_size: int,
                   w: int, n_logical: int, out_dtype):
    del bt_ref                    # consumed by the BlockSpec index maps
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _init_stats(m_ref, l_ref, acc_ref)

    ctx = ctx_ref[b]
    qn = qn_ref[b]

    # per-slot trip count: the page loop runs while this slot still has
    # attendable tokens (ctx + qn = its ragged attend_len) — no pow2
    # window padding, inactive slots (ctx == qn == 0) skip every page
    @pl.when(j * page_size < ctx + qn)
    def _page():
        _ragged_page_update(q_ref[0, 0], k_ref[0, 0], v_ref[0, 0], ctx, qn,
                            j, scale=scale, page_size=page_size, w=w,
                            m_ref=m_ref, l_ref=l_ref, acc_ref=acc_ref)

    @pl.when(j == n_logical - 1)
    def _finalize():
        _finalize_out(o_ref, m_ref, l_ref, acc_ref, out_dtype)


def _ragged_quant_kernel(bt_ref, ctx_ref, qn_ref, q_ref, kc_ref, ks_ref,
                         vc_ref, vs_ref, lut_ref, o_ref, m_ref, l_ref,
                         acc_ref, *, scale: float, page_size: int, w: int,
                         n_logical: int, out_dtype):
    """Fused-LUT ragged megakernel: K/V pages stream as uint8 codes +
    per-token scale, the <=256-entry codebook sits in VMEM for the whole
    grid, and dequantization happens page-by-page right before the MXU —
    the §3.2 memory win on the one launch the decode tick makes."""
    del bt_ref
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _init_stats(m_ref, l_ref, acc_ref)

    ctx = ctx_ref[b]
    qn = qn_ref[b]

    @pl.when(j * page_size < ctx + qn)
    def _page():
        lut = lut_ref[...]
        k = jnp.take(lut, kc_ref[0, 0].astype(jnp.int32), axis=0)
        v = jnp.take(lut, vc_ref[0, 0].astype(jnp.int32), axis=0)
        _ragged_page_update(q_ref[0, 0], k, v, ctx, qn, j, scale=scale,
                            page_size=page_size, w=w, m_ref=m_ref,
                            l_ref=l_ref, acc_ref=acc_ref,
                            k_scale=ks_ref[0, 0][:, 0][None, :],
                            v_scale=vs_ref[0, 0][:, 0][None, :])

    @pl.when(j == n_logical - 1)
    def _finalize():
        _finalize_out(o_ref, m_ref, l_ref, acc_ref, out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("w", "out_dtype", "interpret"))
def paged_decode_ragged_pallas(q, k_pages, v_pages, block_table, ctx_len,
                               q_len, *, w: int, out_dtype=None,
                               interpret: bool = False):
    """Ragged decode-window attention in one launch.

    q: (B, Hkv, R, dh) with R = rep * w window rows per KV head
    (rep-major: row ``r * w + i`` is window position i of query head r);
    ``w`` is the static window length (spec K+1; 1 = plain decode);
    q_len: (B,) int32 valid rows per slot (ragged; rows past it come back
    zero); ctx_len: (B,) int32 tokens in the pages before the window.
    Pools/block_table as in ``paged_attention_pallas``.
    Returns (B, Hkv, R, dh).
    """
    b, hkv, rows, dh = q.shape
    _, _, page_size, _ = k_pages.shape
    max_pages = block_table.shape[1]
    out_dtype = out_dtype or q.dtype
    scale = 1.0 / (dh ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,             # block_table, ctx_len, q_len
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rows, dh),
                         lambda bb, h, j, bt, ctx, qn: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, dh),
                         lambda bb, h, j, bt, ctx, qn: (bt[bb, j], h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, dh),
                         lambda bb, h, j, bt, ctx, qn: (bt[bb, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, dh),
                               lambda bb, h, j, bt, ctx, qn: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),     # running max m
            pltpu.VMEM((rows, 1), jnp.float32),     # running denom l
            pltpu.VMEM((rows, dh), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_kernel, scale=scale, page_size=page_size,
                          w=w, n_logical=max_pages, out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, dh), out_dtype),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, ctx_len, q_len, q, k_pages, v_pages)


@functools.partial(jax.jit,
                   static_argnames=("w", "out_dtype", "interpret"))
def paged_decode_ragged_quant_pallas(q, k_codes, k_scale, v_codes, v_scale,
                                     block_table, ctx_len, q_len, lut, *,
                                     w: int, out_dtype=None,
                                     interpret: bool = False):
    """Fused-LUT ragged decode window over quantized (codes + scale) KV
    pools: same grid and per-row causal masking as
    ``paged_decode_ragged_pallas``, but each streamed page is 1-byte
    codes + per-token scale, dequantized in VMEM against the resident
    codebook before the MXU. Args as ``paged_attention_quant_pallas``
    plus ``q_len``/``w``. Returns (B, Hkv, R, dh)."""
    b, hkv, rows, dh = q.shape
    _, _, page_size, _ = k_codes.shape
    max_pages = block_table.shape[1]
    out_dtype = out_dtype or q.dtype
    scale = 1.0 / (dh ** 0.5)

    def page_spec(width):
        return pl.BlockSpec(
            (1, 1, page_size, width),
            lambda bb, h, j, bt, ctx, qn: (bt[bb, j], h, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,             # block_table, ctx_len, q_len
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rows, dh),
                         lambda bb, h, j, bt, ctx, qn: (bb, h, 0, 0)),
            page_spec(dh),                 # k codes
            page_spec(1),                  # k scale
            page_spec(dh),                 # v codes
            page_spec(1),                  # v scale
            pl.BlockSpec(lut.shape,        # whole LUT, VMEM-resident
                         lambda bb, h, j, bt, ctx, qn: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, dh),
                               lambda bb, h, j, bt, ctx, qn: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),     # running max m
            pltpu.VMEM((rows, 1), jnp.float32),     # running denom l
            pltpu.VMEM((rows, dh), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_quant_kernel, scale=scale,
                          page_size=page_size, w=w, n_logical=max_pages,
                          out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, dh), out_dtype),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, ctx_len, q_len, q, k_codes, k_scale, v_codes, v_scale,
      lut)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def paged_attention_pallas(q, k_pages, v_pages, block_table, ctx_len, *,
                           out_dtype=None, interpret: bool = False):
    """q: (B, Hkv, rep, dh); k_pages/v_pages: (n_pages, Hkv, page_size, dh);
    block_table: (B, max_pages) int32; ctx_len: (B,) int32 — positions
    < ctx_len are attended. Returns (B, Hkv, rep, dh)."""
    b, hkv, rep, dh = q.shape
    _, _, page_size, _ = k_pages.shape
    max_pages = block_table.shape[1]
    out_dtype = out_dtype or q.dtype
    scale = 1.0 / (dh ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,             # block_table, ctx_len
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, dh),
                         lambda bb, h, j, bt, ctx: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, dh),
                         lambda bb, h, j, bt, ctx: (bt[bb, j], h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, dh),
                         lambda bb, h, j, bt, ctx: (bt[bb, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, dh),
                               lambda bb, h, j, bt, ctx: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),      # running max m
            pltpu.VMEM((rep, 1), jnp.float32),      # running denom l
            pltpu.VMEM((rep, dh), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, page_size=page_size,
                          n_logical=max_pages, out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, dh), out_dtype),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, ctx_len, q, k_pages, v_pages)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def paged_attention_quant_pallas(q, k_codes, k_scale, v_codes, v_scale,
                                 block_table, ctx_len, lut, *,
                                 out_dtype=None, interpret: bool = False):
    """Fused-dequant paged decode attention (quantized KV pools).

    q: (B, Hkv, rep, dh); k_codes/v_codes: (n_pages, Hkv, page_size, dh)
    uint8 codebook codes; k_scale/v_scale: (n_pages, Hkv, page_size, 1)
    f32 per-token scales; lut: (2^w,) f32 codebook (spx.codebook of the KV
    scheme — a static per-scheme constant); block_table/ctx_len as in
    ``paged_attention_pallas``. Returns (B, Hkv, rep, dh).

    Same grid and online-softmax pipeline as the unquantized kernel; the
    only difference is that each streamed page is 1-byte codes + scale
    instead of act-dtype values, and ``lut[codes] * scale`` runs on the
    VPU right before the MXU consumes the page.
    """
    b, hkv, rep, dh = q.shape
    _, _, page_size, _ = k_codes.shape
    max_pages = block_table.shape[1]
    out_dtype = out_dtype or q.dtype
    scale = 1.0 / (dh ** 0.5)

    def page_spec(width):
        return pl.BlockSpec((1, 1, page_size, width),
                            lambda bb, h, j, bt, ctx: (bt[bb, j], h, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,             # block_table, ctx_len
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, dh),
                         lambda bb, h, j, bt, ctx: (bb, h, 0, 0)),
            page_spec(dh),                 # k codes
            page_spec(1),                  # k scale
            page_spec(dh),                 # v codes
            page_spec(1),                  # v scale
            pl.BlockSpec(lut.shape,        # whole LUT, VMEM-resident
                         lambda bb, h, j, bt, ctx: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, dh),
                               lambda bb, h, j, bt, ctx: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),      # running max m
            pltpu.VMEM((rep, 1), jnp.float32),      # running denom l
            pltpu.VMEM((rep, dh), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_quant_kernel, scale=scale, page_size=page_size,
                          n_logical=max_pages, out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, dh), out_dtype),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, ctx_len, q, k_codes, k_scale, v_codes, v_scale, lut)
