"""Pallas TPU kernel: single-token decode attention over a paged KV cache.

Serving's decode step is the paper's §3.1 pipeline with a twist: the K/V
"matrix" is no longer contiguous — it is scattered across fixed-size pages
owned by the sequence (see serving/kv_cache.py). The block table is a
*scalar-prefetch* argument (pltpu.PrefetchScalarGridSpec), so the physical
page index is known to the DMA engine before the grid step runs: the
gather happens in the BlockSpec index_map, and the inner loop is the same
double-buffered stream-pages-while-MXU-works pipeline as flash attention —
one (K, V) page pair in flight per (sequence, KV head) while the current
page's QK^T/PV runs, with running (m, l) softmax statistics in VMEM
scratch.

Grid: (B, Hkv, max_pages), pages innermost. GQA is handled by blocking the
query as (rep, dh) per KV head — the ``rep`` query heads that share a KV
head ride in one block and reuse the streamed page. Pages past a
sequence's context length are skipped (pl.when), and positions beyond
``ctx_len`` inside the last page are masked; unused block-table slots
point at page 0, whose DMA is wasted but whose values are never read.

Page size comes from ``runtime.planner.plan_kv_pages`` — the same
VMEM-budget model the matmul tiles use.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import pallas_compiler_params

__all__ = ["paged_attention_pallas"]

_NEG_INF = -1e30


def _kernel(bt_ref, ctx_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale: float, page_size: int, n_logical: int,
            out_dtype):
    del bt_ref                    # consumed by the BlockSpec index maps
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = ctx_ref[b]

    @pl.when(j * page_size < ctx)
    def _page():
        q = q_ref[0, 0]                    # (rep, dh)
        k = k_ref[0, 0]                    # (page_size, dh)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rep = q.shape[0]
        pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (rep, page_size), 1)
        s = jnp.where(pos < ctx, s, _NEG_INF)

        m_prev = m_ref[...]                # (rep, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)             # (rep, page_size)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_logical - 1)
    def _finalize():
        # ctx == 0 rows (inactive slots) never ran _page: l == 0, out == 0
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def paged_attention_pallas(q, k_pages, v_pages, block_table, ctx_len, *,
                           out_dtype=None, interpret: bool = False):
    """q: (B, Hkv, rep, dh); k_pages/v_pages: (n_pages, Hkv, page_size, dh);
    block_table: (B, max_pages) int32; ctx_len: (B,) int32 — positions
    < ctx_len are attended. Returns (B, Hkv, rep, dh)."""
    b, hkv, rep, dh = q.shape
    _, _, page_size, _ = k_pages.shape
    max_pages = block_table.shape[1]
    out_dtype = out_dtype or q.dtype
    scale = 1.0 / (dh ** 0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,             # block_table, ctx_len
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rep, dh),
                         lambda bb, h, j, bt, ctx: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, dh),
                         lambda bb, h, j, bt, ctx: (bt[bb, j], h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, dh),
                         lambda bb, h, j, bt, ctx: (bt[bb, j], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, dh),
                               lambda bb, h, j, bt, ctx: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),      # running max m
            pltpu.VMEM((rep, 1), jnp.float32),      # running denom l
            pltpu.VMEM((rep, dh), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, page_size=page_size,
                          n_logical=max_pages, out_dtype=out_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, dh), out_dtype),
        compiler_params=pallas_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, ctx_len, q, k_pages, v_pages)
