"""OLMoE-1B-7B: 64 experts top-8, 16 layers. [arXiv:2409.02060; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    mlp_variant="swiglu", norm="rmsnorm",
    n_experts=64, top_k=8,
    pattern=("attn+moe",),
    source="arXiv:2409.02060",
)
