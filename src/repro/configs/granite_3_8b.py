"""IBM Granite-3 8B: dense GQA transformer.
[hf:ibm-granite/granite-3.0-8b-base family; hf-verified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab_size=49155,
    mlp_variant="swiglu", norm="rmsnorm",
    pattern=("attn+dense",),
    source="hf:ibm-granite/granite-3.0-2b-base",
)
