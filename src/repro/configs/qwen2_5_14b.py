"""Qwen2.5-14B: dense GQA with QKV bias. [hf:Qwen/Qwen2.5-14B; hf-verified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064,
    mlp_variant="swiglu", norm="rmsnorm", qkv_bias=True,
    rope_theta=1000000.0,
    pattern=("attn+dense",),
    source="hf:Qwen/Qwen2.5-14B",
)
