"""Kimi K2: 1T-param MoE, 384 experts top-8 + 1 shared, 61 layers.
All layers MoE here (real K2 has one dense first layer; scan homogeneity —
see DESIGN.md §8). [arXiv:2501 Kimi K2 report; paper-table]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840,
    mlp_variant="swiglu", norm="rmsnorm",
    n_experts=384, top_k=8, n_shared_experts=1,
    pattern=("attn+moe",),
    source="arXiv:2501.kimi2",
)
