"""xLSTM-350m: mLSTM + sLSTM blocks (attention-free; runs long_500k).
Period of 4: three mLSTM then one sLSTM (7:1 in the paper at 1.3B scale;
3:1 at 350m keeps the same ingredients at 24 layers). d_ff=0 per the
assignment (blocks carry their own projections). [arXiv:2405.04517]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    norm="layernorm", tie_embeddings=True,
    pattern=("mlstm", "mlstm", "mlstm", "slstm+dense"),
    ssm_expand=2, ssm_d_conv=4, lstm_heads=4,
    source="arXiv:2405.04517",
)
