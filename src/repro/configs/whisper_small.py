"""Whisper-small backbone: 12L encoder + 12L decoder, d=768, 12 heads,
LayerNorm + GELU. Conv audio frontend is a STUB per the assignment —
inputs are precomputed frame embeddings (B, 1500, 768).
[arXiv:2212.04356]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    mlp_variant="mlp", act="gelu", norm="layernorm",
    enc_dec=True, n_enc_layers=12, enc_seq_len=1500, frontend="audio",
    pattern=("xdec+dense",),
    source="arXiv:2212.04356",
)
