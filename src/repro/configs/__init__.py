"""Config registry: ``get_config(name)`` / ``list_configs()`` / per-arch
modules. Each assigned architecture has its own module with the exact
published dimensions; ``reduced()`` builds the family-preserving small config
used by CPU smoke tests."""
from __future__ import annotations

import dataclasses
import importlib

from .base import ArchConfig, LM_SHAPES, ShapeConfig

_ARCH_MODULES = [
    "granite_3_8b", "gemma_2b", "qwen2_5_14b", "minitron_4b", "xlstm_350m",
    "kimi_k2_1t_a32b", "olmoe_1b_7b", "whisper_small", "jamba_1_5_large_398b",
    "qwen2_vl_2b", "paper_mlp",
]

_REGISTRY: dict[str, ArchConfig] = {}


def _load():
    if _REGISTRY:
        return
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        cfg = mod.CONFIG
        _REGISTRY[cfg.name] = cfg


def get_config(name: str) -> ArchConfig:
    _load()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _load()
    return sorted(_REGISTRY)


def assigned_archs() -> list[str]:
    """The 10 assigned architectures (excludes the paper's own MLP)."""
    _load()
    return sorted(n for n in _REGISTRY if n != "paper-mlp")


def reduced(cfg: ArchConfig, *, n_layers: int | None = None,
            d_model: int = 64, vocab: int = 512) -> ArchConfig:
    """Family-preserving shrink for CPU smoke tests: same pattern / mixer /
    ffn kinds / gqa ratio, tiny dims."""
    n_per_pattern = len(cfg.pattern)
    layers = n_layers or n_per_pattern
    layers = max(layers, n_per_pattern)
    layers -= layers % n_per_pattern
    heads = max(2, min(4, cfg.n_heads))
    kv = max(1, heads * cfg.n_kv_heads // cfg.n_heads)
    while heads % kv:
        kv += 1
    head_dim = d_model // heads if cfg.head_dim == 0 else 32
    mrope = cfg.mrope_sections
    if mrope is not None:
        half = head_dim // 2
        tot = sum(mrope)
        scaled = [max(1, half * s // tot) for s in mrope]
        scaled[-1] += half - sum(scaled)
        mrope = tuple(scaled)
    return dataclasses.replace(
        cfg,
        n_layers=layers,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=head_dim,
        mrope_sections=mrope,
        d_ff=d_model * 2,
        vocab_size=vocab,
        n_experts=min(cfg.n_experts, 8) if cfg.is_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        # no token dropping at smoke scale: capacity drops make train-vs-
        # decode comparisons nondeterministic (prod keeps 1.25)
        capacity_factor=64.0 if cfg.is_moe else cfg.capacity_factor,
        ssm_d_state=8,
        ssm_dt_rank=8,
        lstm_heads=2,
        n_enc_layers=n_per_pattern if cfg.enc_dec else 0,
        enc_seq_len=16 if cfg.enc_dec else cfg.enc_seq_len,
    )


__all__ = ["ArchConfig", "ShapeConfig", "LM_SHAPES", "get_config",
           "list_configs", "assigned_archs", "reduced"]
