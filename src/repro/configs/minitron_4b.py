"""Minitron-4B (pruned Nemotron): squared-relu ungated FFN.
[arXiv:2407.14679; hf-verified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256000,
    mlp_variant="relu2", act="relu2", norm="layernorm",
    pattern=("attn+dense",),
    source="arXiv:2407.14679",
)
