"""Qwen2-VL-2B backbone: M-RoPE (t/h/w sections 16/24/24), GQA kv=2.
Vision tower is a STUB per the assignment (patch embeddings precomputed);
the M-RoPE position streams are real inputs. [arXiv:2409.12191; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    mlp_variant="swiglu", norm="rmsnorm", qkv_bias=True,
    mrope_sections=(16, 24, 24),
    pattern=("attn+dense",), frontend="vision",
    source="arXiv:2409.12191",
)
