"""The paper's own model (§4.1): 784-128-10 sigmoid MLP."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paper-mlp", family="mlp",
    n_layers=2, d_model=128, n_heads=1, n_kv_heads=1, head_dim=128,
    d_ff=128, vocab_size=10,
    mlp_variant="mlp", act="sigmoid", norm="layernorm",
    pattern=("attn+dense",),  # unused; the MLP has its own model module
    source="paper §4.1",
)
