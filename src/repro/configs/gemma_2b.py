"""Gemma 2B: MQA (1 KV head), GeGLU, head_dim=256, huge vocab.
[arXiv:2403.08295; hf-verified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    mlp_variant="geglu", norm="rmsnorm", tie_embeddings=True,
    pattern=("attn+dense",),
    source="arXiv:2403.08295",
)
