"""ArchConfig: one declarative description per architecture. All 10 assigned
architectures + the paper's own MLP are instances; models/ and launch/ consume
nothing but this."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


#: The assigned input-shape set (LM family; seq_len x global_batch).
LM_SHAPES = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|audio|vlm|mlp
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # ffn / activation
    mlp_variant: str = "swiglu"   # swiglu|geglu|relu2|mlp
    act: str = "gelu"             # for ungated variants
    norm: str = "rmsnorm"
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple] = None   # qwen2-vl M-RoPE
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # layer pattern: per-period slot kinds; n_layers % len(pattern) == 0.
    # slots: "attn+dense" | "attn+moe" | "attn" (no ffn) | "mamba+dense" |
    #        "mamba+moe" | "mamba" | "mlstm" | "slstm+dense" | "xdec+dense"
    pattern: tuple = ("attn+dense",)
    # ssm hyperparams
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0          # 0 -> d_model//16
    lstm_heads: int = 4
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 1500       # stub frontend output length
    # modality frontend stub: None | "audio" | "vision"
    frontend: Optional[str] = None
    # capabilities
    source: str = ""

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: no full-attention prefill dependence —
        SSM/hybrid families only (DESIGN.md §5)."""
        return self.family in ("ssm", "hybrid")

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, \
            (self.name, self.n_layers, self.pattern)
        return self.n_layers // len(self.pattern)

    def shapes(self):
        """The assigned shape cells for this arch, with skip reasons."""
        out = []
        for s in LM_SHAPES:
            if s.name == "long_500k" and not self.subquadratic:
                out.append((s, "skipped(full-attention)"))
            else:
                out.append((s, None))
        return out

    def param_count_estimate(self) -> int:
        """Analytic parameter count (embedding + per-layer), for 6ND."""
        d, dh = self.d_model, self.dh
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        per_period = 0
        for slot in self.pattern:
            mixer = slot.split("+")[0]
            ffn = slot.split("+")[1] if "+" in slot else None
            if mixer in ("attn", "xdec"):
                qo = d * self.n_heads * dh * 2
                kv = d * self.n_kv_heads * dh * 2
                per_period += qo + kv
                if mixer == "xdec":
                    per_period += qo + kv          # cross-attention
            elif mixer == "mamba":
                di = self.ssm_expand * d
                dtr = self.ssm_dt_rank or max(16, d // 16)
                per_period += (d * 2 * di + di * (dtr + 2 * self.ssm_d_state)
                               + dtr * di + di * self.ssm_d_state + di
                               + di * d)
            elif mixer == "mlstm":
                di = self.ssm_expand * d
                per_period += d * 2 * di + 3 * di * di + di * d
            elif mixer == "slstm":
                per_period += d * 4 * d + 4 * d * (d // self.lstm_heads) \
                    + d * d
            if ffn == "dense":
                n_mat = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
                d_ff = self.d_ff or ((4 * d // 3 + 127) // 128 * 128)
                per_period += n_mat * d * d_ff
            elif ffn == "moe":
                n_mat = 3
                per_period += (self.n_experts + self.n_shared_experts) \
                    * n_mat * d * self.d_ff + d * self.n_experts
        total += per_period * self.n_periods
        if self.enc_dec:
            enc_per = (d * self.n_heads * dh * 2 + d * self.n_kv_heads * dh * 2
                       + 2 * d * self.d_ff)
            total += enc_per * self.n_enc_layers
        return total

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count_estimate()
        full = self.param_count_estimate()
        n_mat = 3
        d = self.d_model
        moe_slots = sum(1 for s in self.pattern if s.endswith("+moe"))
        expert_params_total = (self.n_experts * n_mat * d * self.d_ff
                               * moe_slots * self.n_periods)
        active_expert = (self.top_k * n_mat * d * self.d_ff
                         * moe_slots * self.n_periods)
        return full - expert_params_total + active_expert
