"""Jamba-1.5-Large (398B): Mamba+attention 1:7 interleave, MoE 16e top-2 on
every other layer. Period of 8 = [attn, 7x mamba], MoE on odd in-period
slots; 72 layers = 9 periods. [arXiv:2403.19887; hf]"""
from .base import ArchConfig

_PERIOD = (
    "attn+dense", "mamba+moe", "mamba+dense", "mamba+moe",
    "mamba+dense", "mamba+moe", "mamba+dense", "mamba+moe",
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    mlp_variant="swiglu", norm="rmsnorm",
    n_experts=16, top_k=2,
    pattern=_PERIOD,
    ssm_d_state=16, ssm_d_conv=4, ssm_expand=2, ssm_dt_rank=256,
    source="arXiv:2403.19887",
)
