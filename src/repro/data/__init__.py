from .mnist import SynthDigits, make_dataset
from .tokens import TokenStream, markov_batch
