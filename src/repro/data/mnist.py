"""Procedural MNIST-like digits (offline container: no downloads).

Each class is a set of stroke segments on a 28x28 canvas; samples add
per-example jitter (translation, thickness, amplitude noise) so a classifier
has real within-class variance to learn. Not MNIST pixels, but the same
task shape: 784-dim grayscale in [0,1], 10 classes — enough to reproduce
the paper's §4.1 training curves and the quantized-inference accuracy
comparison on real learned weights.
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_dataset", "SynthDigits"]

# stroke endpoints per digit on a [0,1]^2 canvas: (x0, y0, x1, y1)
_STROKES = {
    0: [(.3, .2, .7, .2), (.7, .2, .7, .8), (.7, .8, .3, .8), (.3, .8, .3, .2)],
    1: [(.5, .2, .5, .8), (.4, .3, .5, .2)],
    2: [(.3, .3, .5, .2), (.5, .2, .7, .3), (.7, .3, .3, .8), (.3, .8, .7, .8)],
    3: [(.3, .2, .7, .3), (.7, .3, .5, .5), (.5, .5, .7, .7), (.7, .7, .3, .8)],
    4: [(.6, .2, .3, .6), (.3, .6, .75, .6), (.65, .4, .65, .85)],
    5: [(.7, .2, .3, .2), (.3, .2, .3, .5), (.3, .5, .7, .6), (.7, .6, .6, .8),
        (.6, .8, .3, .8)],
    6: [(.65, .2, .35, .5), (.35, .5, .35, .75), (.35, .75, .65, .75),
        (.65, .75, .65, .55), (.65, .55, .35, .55)],
    7: [(.3, .2, .7, .2), (.7, .2, .45, .8)],
    8: [(.5, .2, .3, .35), (.3, .35, .7, .6), (.7, .6, .5, .8), (.5, .8, .3, .6),
        (.3, .6, .7, .35), (.7, .35, .5, .2)],
    9: [(.65, .45, .35, .45), (.35, .45, .35, .25), (.35, .25, .65, .25),
        (.65, .25, .65, .8), (.65, .8, .45, .85)],
}


def _render(strokes, rng, size=28, thickness=1.3):
    img = np.zeros((size, size), np.float32)
    dx, dy = rng.uniform(-2.5, 2.5, 2)
    th = thickness * rng.uniform(0.7, 1.5)
    amp = rng.uniform(0.75, 1.0)
    jit = rng.uniform(-0.025, 0.025, (len(strokes), 4))
    ys, xs = np.mgrid[0:size, 0:size]
    for (x0, y0, x1, y1), j in zip(strokes, jit):
        x0, y0, x1, y1 = (np.array([x0, y0, x1, y1]) + j) * size
        x0 += dx; x1 += dx; y0 += dy; y1 += dy
        # distance from each pixel to the segment
        px, py = xs + 0.5, ys + 0.5
        vx, vy = x1 - x0, y1 - y0
        ll = max(vx * vx + vy * vy, 1e-6)
        t = np.clip(((px - x0) * vx + (py - y0) * vy) / ll, 0, 1)
        d2 = (px - (x0 + t * vx)) ** 2 + (py - (y0 + t * vy)) ** 2
        img = np.maximum(img, amp * np.exp(-d2 / (2 * th * th)))
    img += rng.normal(0, 0.02, img.shape).astype(np.float32)
    return np.clip(img, 0, 1)


def make_dataset(n: int, *, seed: int = 0, flat: bool = True):
    """Returns (x (n, 784) float32 in [0,1], y (n,) int32)."""
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, 10, n).astype(np.int32)
    xs = np.stack([_render(_STROKES[int(c)], rng) for c in ys])
    if flat:
        xs = xs.reshape(n, -1)
    return xs.astype(np.float32), ys


class SynthDigits:
    """Mini-batch iterator matching the paper's training setup (B=64)."""

    def __init__(self, n_train=8192, n_test=2048, batch_size=64, seed=0):
        self.x_train, self.y_train = make_dataset(n_train, seed=seed)
        self.x_test, self.y_test = make_dataset(n_test, seed=seed + 1)
        self.batch_size = batch_size
        self._rng = np.random.default_rng(seed + 2)

    def batches(self, epochs: int = 1):
        n = len(self.x_train)
        for _ in range(epochs):
            order = self._rng.permutation(n)
            for i in range(0, n - self.batch_size + 1, self.batch_size):
                idx = order[i:i + self.batch_size]
                yield self.x_train[idx], self.y_train[idx]
