"""Synthetic token pipeline with background prefetch.

Sequences are drawn from a seeded order-2 Markov chain over the vocab with a
low-entropy transition table, so an LM has real structure to learn (loss
drops well below uniform) without any external corpus. The pipeline runs
generation on a worker thread with a bounded queue — the host-side
prefetch/backpressure that keeps device steps from stalling on data (and the
lever the straggler watchdog monitors).
"""
from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["TokenStream", "markov_batch"]


def _transition_rows(vocab: int, branch: int, seed: int):
    rng = np.random.default_rng(seed)
    nexts = rng.integers(0, vocab, size=(vocab, branch))
    return nexts


def markov_batch(rng, nexts, batch: int, seq: int):
    vocab, branch = nexts.shape
    out = np.empty((batch, seq + 1), np.int32)
    out[:, 0] = rng.integers(0, vocab, batch)
    for t in range(seq):
        choice = rng.integers(0, branch, batch)
        out[:, t + 1] = nexts[out[:, t], choice]
    return out


class TokenStream:
    """Iterator of {'tokens', 'labels'} batches with worker prefetch."""

    def __init__(self, vocab: int, batch: int, seq: int, *, branch: int = 4,
                 seed: int = 0, prefetch: int = 4):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self._nexts = _transition_rows(vocab, branch, seed)
        self._rng = np.random.default_rng(seed + 1)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            seqs = markov_batch(self._rng, self._nexts, self.batch, self.seq)
            batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.2)
                    break
                except queue.Full:
                    continue

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
