"""Analytical block planner: per-shape (bm, bn, bk) / (bq, bkv) / KV-page
selection.

The paper's §3.1 soundness condition — loading must stay ahead of compute —
is evaluated analytically by ``core/pipeline.plan_matmul_blocks``; this
module turns it into the *execution plan* the kernels actually run with,
instead of the old one-size-fits-all ``DEFAULT_BM/BN/BK`` constants:

  * candidate blocks are filtered to exact divisors of (N, K) (and (Sq,
    Skv) for attention) so the Pallas grid tiles the problem with no
    remainder — M alone is padded by the ops wrapper;
  * the surviving candidate maximizing (pipelined, margin, -vmem) under the
    VMEM budget wins; plans are lru-cached per shape so planning is free
    after the first trace;
  * ``None`` means no legal blocking exists (ragged dims) and the caller
    falls back to the jnp reference path — exactly the old behavior, now in
    one place.

The same VMEM-budget model sizes the serving KV pages (``plan_kv_pages``):
a page is the unit the paged-attention decode kernel streams HBM→VMEM per
grid step, so it is chosen like any other tile — double-buffered K+V page
pair under ``VMEM_BUDGET_FRACTION``, floored at the dtype's sublane tile.

All sizes in this module are **element counts** (tokens, rows, columns)
except fields and helpers explicitly suffixed ``_bytes``; activation /
weight widths enter as ``act_bytes`` / ``weight_bits``.

Caching: every ``plan_*`` entry point memoizes per concrete shape tuple via
``functools.lru_cache`` — the first call per shape does the search, later
calls (including every jit retrace) are dict hits. ``clear_plan_cache()``
drops all cached plans and measured-autotune winners (tests use it when
flipping env overrides).

Environment overrides (read at call time, not import time):
  REPRO_BLOCKS_MATMUL="bm,bn,bk"  pin matmul blocks (divisibility checked)
  REPRO_BLOCKS_ATTN="bq,bkv"      pin attention blocks
  REPRO_PAGE_SIZE=N               pin the KV page size (tokens per page)
  REPRO_AUTOTUNE=1                measured autotuning: ops wrappers time the
                                  top analytical candidates on the real
                                  kernel and cache the winner per shape
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable, Optional

from repro.core.pipeline import HwSpec, TPU_V5E, plan_matmul_blocks

__all__ = [
    "MatmulBlocks", "AttentionBlocks", "KVPagePlan", "FusedDecodePlan",
    "StateReservation",
    "ShardBudget",
    "plan_matmul", "plan_attention", "plan_kv_pages", "plan_seq_pages",
    "plan_resume_pages", "plan_seq_state", "plan_shard_budget",
    "plan_fused_decode", "fused_decode_key", "matmul_candidates",
    "autotune_enabled", "measured_best", "measured_plan",
    "clear_plan_cache", "DEFAULT_BM", "VMEM_BUDGET_FRACTION",
]

# bm candidate ceiling for tiny-M problems (M is padded to the chosen bm,
# so candidates above this only waste padding). This is the only default
# tile constant left in the tree — kernels take explicit blocks now.
DEFAULT_BM = 256

#: fraction of per-core VMEM a plan may claim (double buffers + scratch
#: accounting lives in core/pipeline._block_cost)
VMEM_BUDGET_FRACTION = 0.9

_BLOCK_CANDIDATES = (2048, 1024, 512, 384, 256, 128, 64, 32, 16, 8)


@dataclasses.dataclass(frozen=True)
class MatmulBlocks:
    bm: int
    bn: int
    bk: int
    pipelined: bool          # t_load <= t_compute (paper's §3.1 condition)
    margin: float            # compute/load ratio; >1 => DMA fully hidden
    vmem_bytes: int


@dataclasses.dataclass(frozen=True)
class AttentionBlocks:
    bq: int
    bkv: int
    pipelined: bool
    margin: float
    vmem_bytes: int


@dataclasses.dataclass(frozen=True)
class KVPagePlan:
    """Geometry for the paged KV cache (serving decode path).

    page_size    tokens per page — the unit the paged-attention kernel
                 streams per grid step AND the allocator's granularity
    pipelined    §3.1 condition for the decode kernel's page loop
    margin       compute/load ratio for one (K, V) page pair
    vmem_bytes   kernel working set: double-buffered K+V page pair +
                 q/acc/stats scratch, in bytes
    """
    page_size: int
    pipelined: bool
    margin: float
    vmem_bytes: int


def _divisors(dim: int, *, even: bool = False) -> tuple[int, ...]:
    out = tuple(c for c in _BLOCK_CANDIDATES
                if c <= dim and dim % c == 0
                and (not even or c % 2 == 0))
    # the §3.1 margin is block-size-neutral along K (load and compute both
    # scale linearly), so an unfiltered search ties and the min-VMEM
    # tie-break degenerates to 8-wide tiles. Floor candidates at the MXU
    # tile (128) when the dim admits one — sub-MXU tiles waste the systolic
    # array no matter what the byte model says.
    if out:
        floor = min(128, max(out))
        out = tuple(c for c in out if c >= floor)
    return out


def matmul_candidates(m: int, k: int, n: int, *,
                      packed: bool = False) -> tuple:
    """(bm, bn, bk) candidate tuples under the divisibility rules the
    Pallas wrapper needs: bn | n, bk | k (bn even when int4-packed); bm is
    free (M is padded).

    Units: ``m``/``k``/``n`` are matrix dims in elements; returned
    candidates are tile dims in elements. Pure function, no caching —
    callers (``_plan_matmul_cached``, the autotuner) cache downstream.
    """
    bm_c = tuple(c for c in _BLOCK_CANDIDATES if c <= max(m, DEFAULT_BM))
    bn_c = _divisors(n, even=packed)
    bk_c = _divisors(k)
    return bm_c, bn_c, bk_c


def _env_override(var: str, n_fields: int) -> Optional[tuple[int, ...]]:
    raw = os.environ.get(var)
    if not raw:
        return None
    parts = tuple(int(p) for p in raw.replace(" ", "").split(","))
    if len(parts) != n_fields:
        raise ValueError(f"{var}={raw!r}: expected {n_fields} ints")
    return parts


@functools.lru_cache(maxsize=4096)
def _plan_matmul_cached(m: int, k: int, n: int, weight_bits: int,
                        act_bytes: int, packed: bool,
                        hw: HwSpec) -> Optional[MatmulBlocks]:
    bm_c, bn_c, bk_c = matmul_candidates(m, k, n, packed=packed)
    if not bn_c or not bk_c:
        return None                       # ragged dims: ref fallback
    plan = plan_matmul_blocks(m, n, k, weight_bits=weight_bits,
                              act_bytes=act_bytes, hw=hw,
                              candidates_m=bm_c, candidates_n=bn_c,
                              candidates_k=bk_c,
                              vmem_fraction=VMEM_BUDGET_FRACTION)
    # the tiny-problem fallback inside plan_matmul_blocks ignores the
    # candidate filter; re-check divisibility before trusting it
    if n % plan.bn or k % plan.bk or (packed and plan.bn % 2):
        return None
    return MatmulBlocks(plan.bm, plan.bn, plan.bk, plan.pipelined,
                        plan.margin, plan.vmem_bytes)


def plan_matmul(m: int, k: int, n: int, *, weight_bits: int = 16,
                act_bytes: int = 2, packed: bool = False,
                hw: HwSpec = TPU_V5E) -> Optional[MatmulBlocks]:
    """Blocks for x:(M,K) @ W:(K,N) with b-bit SPx weight codes, or None if
    no legal blocking exists (caller falls back to the ref path).

    Units: ``m``/``k``/``n`` in elements; ``weight_bits`` per weight code
    (4 or 8 for SPx, 16 for dense bf16); ``act_bytes`` per activation
    element; ``MatmulBlocks.vmem_bytes`` is the kernel working set in
    bytes. Cached per (m, k, n, weight_bits, act_bytes, packed, hw);
    ``REPRO_BLOCKS_MATMUL="bm,bn,bk"`` pins the blocks (divisibility still
    checked; returns None — i.e. ref fallback — when the pin is illegal
    for this shape) and bypasses the cache.
    """
    pinned = _env_override("REPRO_BLOCKS_MATMUL", 3)
    if pinned is not None:
        bm, bn, bk = pinned
        if n % bn or k % bk or (packed and bn % 2):
            return None
        return MatmulBlocks(bm, bn, bk, False, 0.0, 0)
    return _plan_matmul_cached(m, k, n, weight_bits, act_bytes, packed, hw)


@functools.lru_cache(maxsize=4096)
def _plan_attention_cached(sq: int, skv: int, dh: int, act_bytes: int,
                           hw: HwSpec) -> Optional[AttentionBlocks]:
    best = None
    for bq in _divisors(sq):
        for bkv in _divisors(skv):
            # per inner grid step: stream the next (K, V) tile pair while
            # the MXU runs QK^T + PV on the current one (q stays resident
            # across the KV loop)
            t_load = 2 * bkv * dh * act_bytes / hw.hbm_bw
            t_compute = 4.0 * bq * bkv * dh / hw.peak_bf16_flops
            vmem = (2 * (bq * dh + 2 * bkv * dh) * act_bytes
                    + bq * dh * 4 + 2 * bq * 4)      # acc + (m, l) scratch
            if vmem > hw.vmem_bytes * VMEM_BUDGET_FRACTION:
                continue
            margin = t_compute / max(t_load, 1e-30)
            plan = AttentionBlocks(bq, bkv, t_load <= t_compute, margin,
                                   int(vmem))
            key = (plan.pipelined, plan.margin, -plan.vmem_bytes)
            if best is None or key > (best.pipelined, best.margin,
                                      -best.vmem_bytes):
                best = plan
    return best


def plan_attention(sq: int, skv: int, dh: int, *, act_bytes: int = 2,
                   hw: HwSpec = TPU_V5E) -> Optional[AttentionBlocks]:
    """(bq, bkv) for flash attention over (Sq, Skv, dh), or None when the
    sequence dims admit no candidate blocking (ref fallback).

    Units: ``sq``/``skv``/``dh`` are element counts; ``act_bytes`` is the
    per-element width of Q/K/V. Cached per (sq, skv, dh, act_bytes, hw);
    the ``REPRO_BLOCKS_ATTN`` override bypasses the cache entirely.
    """
    pinned = _env_override("REPRO_BLOCKS_ATTN", 2)
    if pinned is not None:
        bq, bkv = pinned
        if sq % bq or skv % bkv:
            return None
        return AttentionBlocks(bq, bkv, False, 0.0, 0)
    return _plan_attention_cached(sq, skv, dh, act_bytes, hw)


# ---------------------------------------------------------------------------
# KV page sizing (serving)
# ---------------------------------------------------------------------------

#: tokens-per-page candidates, ascending — ties in the §3.1 score resolve
#: to the SMALLEST page (least fragmentation waste per sequence tail)
_PAGE_CANDIDATES = (8, 16, 32, 64, 128, 256)


def _sublane_floor(act_bytes: int) -> int:
    """Minimum second-to-last tile dim for the cache dtype (TPU tiling:
    f32 -> 8, bf16 -> 16, int8 -> 32). Pages sit on the sublane axis of the
    kernel's (page_size, dh) K/V blocks, so smaller pages than this would
    be padded to a full tile anyway."""
    return max(8, 32 // max(act_bytes, 1))


@functools.lru_cache(maxsize=256)
def _plan_kv_pages_cached(n_kv_heads: int, dh: int, rep: int,
                          act_bytes: int, tok_bytes: int,
                          floor_bytes: int, hw: HwSpec) -> KVPagePlan:
    del n_kv_heads  # the kernel grids over KV heads; per-step cost is 1 head
    best = None
    best_key = None
    for ps in _PAGE_CANDIDATES:
        if ps < _sublane_floor(floor_bytes):
            continue
        # per grid step: stream the next (K, V) page pair for one KV head
        # while the MXU runs QK^T + PV (rep query heads) on the current one.
        # ``tok_bytes`` is one token's K *or* V bytes for one head — dh x
        # act_bytes dense, dh x 1 + 4 for the codes+scale quantized pool
        # (the fused-dequant kernel streams codes, not values).
        t_load = 2 * ps * tok_bytes / hw.hbm_bw
        t_compute = 4.0 * rep * ps * dh / hw.peak_bf16_flops
        vmem = (2 * 2 * ps * tok_bytes           # double-buffered K+V pages
                + rep * dh * act_bytes           # resident q
                + rep * dh * 4 + 2 * rep * 4)    # f32 acc + (m, l) scratch
        if vmem > hw.vmem_bytes * VMEM_BUDGET_FRACTION:
            continue
        margin = t_compute / max(t_load, 1e-30)
        # NOTE: the margin is page-size-neutral (load and compute both scale
        # linearly in page_size), so the score usually ties and the
        # ascending iteration keeps the smallest legal page — exactly what
        # fragmentation wants. The score still matters when VMEM excludes
        # candidates or a future HwSpec breaks the linearity.
        key = (t_load <= t_compute, margin)
        if best is None or key > best_key:
            best = KVPagePlan(ps, t_load <= t_compute, margin, int(vmem))
            best_key = key
    if best is None:                    # dh so large nothing fits: min tile
        ps = _sublane_floor(floor_bytes)
        best = KVPagePlan(ps, False, 0.0, 0)
    return best


def plan_kv_pages(n_kv_heads: int, dh: int, *, rep: int = 1,
                  act_bytes: int = 2, kv_scheme: str | None = None,
                  hw: HwSpec = TPU_V5E) -> KVPagePlan:
    """Tokens-per-page for the paged KV cache.

    Units: ``n_kv_heads``/``dh`` are element counts (the cache page is
    ``page_size x dh`` elements per KV head); ``rep = Hq // Hkv`` is the
    GQA expansion (query heads served per KV page); ``act_bytes`` is the
    cache element width in bytes. ``kv_scheme`` (a core/spx scheme name)
    switches the byte model to the quantized codes+scale page layout —
    ``dh x 1 + 4`` bytes per token side instead of ``dh x act_bytes`` —
    and floors the page at the uint8 sublane tile (32).

    Cached per argument tuple (lru); ``REPRO_PAGE_SIZE=N`` pins the page
    size, bypassing both the model and the cache. Always returns a plan —
    there is no ref-fallback ``None`` here because any page size is legal
    for the allocator; an unpipelined plan just means the decode kernel is
    HBM-bound (which single-token decode always is: margin < 1 whenever
    ``2 * rep * peak_flops_byte < 1``).
    """
    pinned = _env_override("REPRO_PAGE_SIZE", 1)
    if pinned is not None:
        return KVPagePlan(pinned[0], False, 0.0, 0)
    if kv_scheme is not None:
        from repro.core.spx import KV_CODE_BYTES, kv_token_side_bytes
        tok_bytes, floor_bytes = kv_token_side_bytes(dh), KV_CODE_BYTES
    else:
        tok_bytes, floor_bytes = dh * act_bytes, act_bytes
    return _plan_kv_pages_cached(n_kv_heads, dh, rep, act_bytes, tok_bytes,
                                 floor_bytes, hw)


def plan_seq_pages(n_tokens: int, page_size: int, *,
                   shared_tokens: int = 0) -> int:
    """Fresh pages a sequence must reserve at admission.

    The worst-case reservation is ``ceil(n_tokens / page_size)`` pages;
    a matched shared prefix of ``shared_tokens`` tokens maps
    ``shared_tokens // page_size`` of them from the pool's prefix index
    instead (refcount bump, no new page, no prefill work). The floor
    deliberately bills a *partially* reused last page as fresh: that is
    the copy-on-write case — the engine copies the matched page into a
    private one before the sequence writes into it — so the COW
    destination is correctly part of the fresh reservation.

    Units are tokens and pages, which makes the count layout-neutral: a
    page holds ``page_size`` tokens whether its device arrays store dense
    ``act_bytes`` elements or the quantized codes+scale pair
    (``plan_kv_pages`` sizes both layouts to the same token geometry), so
    one reservation model covers plain and kv_quant pools.
    """
    if page_size < 1 or n_tokens < 0 or not 0 <= shared_tokens <= n_tokens:
        raise ValueError((n_tokens, page_size, shared_tokens))
    return -(-n_tokens // page_size) - shared_tokens // page_size


def plan_resume_pages(n_written: int, n_total: int,
                      page_size: int) -> tuple[int, int]:
    """Page plan for resuming a preempted sequence:
    ``(pages_total, pages_restored)``.

    ``pages_total`` is the full worst-case reservation the sequence needs
    back on device (``plan_seq_pages`` of its prompt + max_new budget —
    resumption re-reserves exactly what admission did, so a resumed
    request can never OOM mid-decode any more than a fresh one can).
    ``pages_restored`` is the leading slice of that reservation which
    must be refilled from the host snapshot: the pages covering the
    ``n_written`` tokens that were actually in the cache at preemption
    (the write cursor) — everything past the cursor is unwritten (or a
    rejected speculative tail that was never attended) and restores as
    blank pages for free. No prefix sharing: the restored bytes are
    private by construction.
    """
    if not 0 <= n_written <= n_total:
        raise ValueError((n_written, n_total, page_size))
    return (plan_seq_pages(n_total, page_size),
            plan_seq_pages(n_written, page_size))


@dataclasses.dataclass(frozen=True)
class StateReservation:
    """Per-sequence admission footprint across the StateCache regions:
    ``pages`` of token-paged KV (fresh pages after prefix discount),
    ``slabs`` of recurrent SSM state (0 or 1 — one slab covers every SSM
    slot x period), ``cross`` read-only encoder-output KV entries (0 or 1;
    a prefix-index hit on the frames key costs 0 fresh entries, but the
    reservation bills the miss case — admission is worst-case, like
    pages)."""
    pages: int
    slabs: int
    cross: int


def plan_seq_state(n_tokens: int, page_size: int, *,
                   shared_tokens: int = 0, needs_pages: bool = True,
                   needs_slab: bool = False,
                   needs_cross: bool = False) -> StateReservation:
    """Admission reservation for one sequence under the unified
    state-cache: the ``plan_seq_pages`` token->page model for the
    attention slots (0 pages when the pattern has none — pure-SSM models
    run pageless), plus one slab when any SSM slot needs recurrent state,
    plus one cross entry when the model decodes against encoder output.
    The page/slab/cross split is what ``StateCache.allocate`` checks
    all-or-nothing at admission."""
    pages = plan_seq_pages(n_tokens, page_size,
                           shared_tokens=shared_tokens) if needs_pages \
        else 0
    return StateReservation(pages=pages, slabs=int(bool(needs_slab)),
                            cross=int(bool(needs_cross)))


# ---------------------------------------------------------------------------
# Per-shard budgets (tensor-parallel serving)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardBudget:
    """Where one model shard's serving memory actually goes when the paged
    pools are head-sharded over a ``model`` axis of size ``shards``.

    kv_sharded            the pool's KV-head axis divides evenly, so each
                          shard holds ``kv_heads_per_shard`` of it; when
                          False the pool replicates (each shard holds all
                          heads) and the per-shard numbers equal global
    kv_heads_per_shard    KV heads resident per shard
    page_bytes            ONE page's bytes on one shard (all layers)
    pool_bytes            the whole page pool's bytes on one shard
    slab_bytes            recurrent-slab bytes on one shard — slabs
                          replicate (sequence-private state, no head axis)
    vmem_bytes            the decode kernel's per-step working set; the
                          kernel grids over (slot, kv_head, page) so the
                          per-step set is one head's page pair regardless
                          of how many heads the shard holds — sharding
                          changes grid length, not VMEM pressure
    """
    shards: int
    kv_sharded: bool
    kv_heads_per_shard: int
    page_bytes: int
    pool_bytes: int
    slab_bytes: int
    vmem_bytes: int


@functools.lru_cache(maxsize=256)
def _plan_shard_budget_cached(n_kv_heads: int, dh: int, shards: int,
                              page_size: int, n_pages: int, n_layers: int,
                              slab_bytes: int, tok_side_bytes: int,
                              vmem_bytes: int) -> ShardBudget:
    kv_sharded = shards > 1 and n_kv_heads % shards == 0
    heads = n_kv_heads // shards if kv_sharded else n_kv_heads
    # K + V sides, all paged layers, the shard's resident heads
    page_bytes = 2 * page_size * tok_side_bytes * heads * n_layers
    return ShardBudget(shards=shards, kv_sharded=kv_sharded,
                       kv_heads_per_shard=heads, page_bytes=page_bytes,
                       pool_bytes=page_bytes * n_pages,
                       slab_bytes=slab_bytes, vmem_bytes=vmem_bytes)


def plan_shard_budget(n_kv_heads: int, dh: int, *, shards: int = 1,
                      page_size: int, n_pages: int, n_layers: int = 1,
                      slab_bytes: int = 0, act_bytes: int = 2,
                      kv_scheme: str | None = None,
                      hw: HwSpec = TPU_V5E) -> ShardBudget:
    """Per-shard page/slab/VMEM budget for a tensor-parallel paged engine.

    The page pool is ``(layers, n_pages, Hkv, page_size, dh)`` per K/V
    side; sharding splits the ``Hkv`` axis over ``shards`` model-parallel
    devices when it divides (else the pool replicates — same
    divisibility-or-replicate rule ``ShardingPolicy`` applies to params).
    ``slab_bytes`` (recurrent state, per-sequence) never shards.
    ``kv_scheme`` switches the per-token byte model to the quantized
    codes+scale layout, same as ``plan_kv_pages``. The VMEM figure is the
    decode kernel's per-step working set and is deliberately
    shard-neutral: the kernel's grid covers the shard's heads
    sequentially, so fewer resident heads shorten the grid without
    changing the per-step footprint.
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if kv_scheme is not None:
        from repro.core.spx import kv_token_side_bytes
        tok_side = kv_token_side_bytes(dh)
    else:
        tok_side = dh * act_bytes
    plan = plan_kv_pages(n_kv_heads, dh, act_bytes=act_bytes,
                         kv_scheme=kv_scheme, hw=hw)
    return _plan_shard_budget_cached(n_kv_heads, dh, shards, page_size,
                                     n_pages, n_layers, slab_bytes,
                                     tok_side, plan.vmem_bytes)


# ---------------------------------------------------------------------------
# Fused ragged-decode megakernel sizing (serving)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FusedDecodePlan:
    """VMEM model for one grid step of the ragged decode megakernel.

    The kernel grids over (slot, kv_head, page); per step it holds the
    whole (rep * w, dh) query window + f32 accumulator/stats resident,
    double-buffers one K+V page pair (codes + per-token scale for a
    quantized pool), and — quantized pools only — keeps the <=256-entry
    codebook LUT pinned in VMEM for the entire launch.

    rows         rep * w query rows per grid step (w = spec K+1, or 1)
    lut_bytes    resident codebook bytes (0 for a dense pool)
    pipelined    §3.1 condition for the page loop at this window size
    margin       compute/load ratio for one (K, V) page pair
    vmem_bytes   total per-step working set in bytes
    """
    rows: int
    lut_bytes: int
    pipelined: bool
    margin: float
    vmem_bytes: int


@functools.lru_cache(maxsize=256)
def _plan_fused_decode_cached(dh: int, rep: int, w: int, page_size: int,
                              act_bytes: int, tok_bytes: int,
                              lut_bytes: int, hw: HwSpec) -> FusedDecodePlan:
    rows = rep * w
    # per grid step: stream the next (K, V) page pair while the MXU runs
    # QK^T + PV for all ``rows`` window rows on the current one
    t_load = 2 * page_size * tok_bytes / hw.hbm_bw
    t_compute = 4.0 * rows * page_size * dh / hw.peak_bf16_flops
    vmem = (2 * 2 * page_size * tok_bytes     # double-buffered K+V pages
            + rows * dh * act_bytes           # resident ragged q window
            + rows * dh * 4 + 2 * rows * 4    # f32 acc + (m, l) scratch
            + lut_bytes)                      # whole-launch-resident LUT
    margin = t_compute / max(t_load, 1e-30)
    return FusedDecodePlan(rows, lut_bytes, t_load <= t_compute, margin,
                           int(vmem))


def plan_fused_decode(dh: int, *, rep: int = 1, w: int = 1,
                      page_size: int = 8, act_bytes: int = 2,
                      kv_scheme: str | None = None,
                      hw: HwSpec = TPU_V5E) -> FusedDecodePlan:
    """Working-set model for the ragged decode megakernel.

    Units: ``dh`` and ``page_size`` are element/token counts; ``rep = Hq //
    Hkv``; ``w`` is the static decode window (spec K+1, or 1 for plain
    decode); ``act_bytes`` the query/cache element width. ``kv_scheme``
    switches the streamed-page byte model to the quantized codes+scale
    layout *and* charges the scheme's codebook LUT as VMEM-resident for
    the whole launch (it is prefetched once, not per page).

    Always returns a plan — page geometry was already fixed by
    ``plan_kv_pages`` at pool allocation, so there is no candidate search
    here, just the §3.1 accounting for the window the engine runs. The w
    factor is why the megakernel pays off: compute grows with ``rep * w``
    per streamed page while load stays constant, so the verify window
    pushes ``margin`` toward pipelined where single-row decode is
    hopelessly HBM-bound.
    """
    if kv_scheme is not None:
        from repro.core.spx import (code_width, kv_token_side_bytes,
                                    scheme_levels)
        tok_bytes = kv_token_side_bytes(dh)
        # f32 codebook padded to the code width's power of two (spx.codebook)
        lut_bytes = 4 * (1 << code_width(scheme_levels(kv_scheme)))
    else:
        tok_bytes, lut_bytes = dh * act_bytes, 0
    return _plan_fused_decode_cached(dh, rep, w, page_size, act_bytes,
                                     tok_bytes, lut_bytes, hw)


def fused_decode_key(b: int, hkv: int, rep: int, w: int, dh: int,
                     page_size: int, max_pages: int,
                     kv_scheme: str | None) -> tuple:
    """Measured-autotune / plan cache key for one megakernel workload.

    ``kv_scheme`` and the window ``w`` (spec K+1) are deliberately part of
    the key: a winner measured for a dense pool must not be reused for a
    codes+scale pool of identical shape (different bytes/page, different
    in-kernel dequant work), and a plain-decode winner (w=1) must not leak
    into the verify window's workload (w=K+1) — they share every array
    shape except the query rows.
    """
    return ("paged_decode_ragged", b, hkv, rep, w, dh, page_size,
            max_pages, kv_scheme)


# ---------------------------------------------------------------------------
# Measured autotuning (env/flag-gated)
# ---------------------------------------------------------------------------

_MEASURED: dict = {}


def autotune_enabled() -> bool:
    """True when ``REPRO_AUTOTUNE`` is set to 1/true/measured. Read from
    the environment on every call (no caching) so tests can flip it."""
    return os.environ.get("REPRO_AUTOTUNE", "").lower() in ("1", "true",
                                                            "measured")


def measured_plan(key):
    """Previously measured winner for this shape key, or None. Consulted at
    trace time too (shapes are concrete there), so a winner measured during
    an eager warm-up call applies to every later jitted step. The measured
    table is process-local and cleared by ``clear_plan_cache()``."""
    return _MEASURED.get(key)


def measured_best(key, plans, runner: Callable[[object], float]):
    """Time each candidate plan with ``runner`` (seconds per call on the
    real kernel + real arrays) and cache the winner per shape key. The ops
    wrappers call this only when ``autotune_enabled()``; the analytical
    plan always seeds the candidate list so measurement can only improve
    on it."""
    if key in _MEASURED:
        return _MEASURED[key]
    best, best_t = None, float("inf")
    for plan in plans:
        try:
            t = runner(plan)
        except Exception as e:     # candidate doesn't compile on this target
            print(f"[planner] autotune candidate {plan} failed: {e!r}")
            continue
        if t < best_t:
            best, best_t = plan, t
    if best is None:
        # nothing measured: return the analytical seed WITHOUT caching so a
        # transient failure doesn't pin a known-bad plan for the process
        return plans[0]
    _MEASURED[key] = best
    return best


def clear_plan_cache():
    """Drop every cached plan: analytical matmul/attention/page plans AND
    measured-autotune winners. Needed after changing a ``REPRO_*`` planner
    env var mid-process — plans are cached per shape, not per environment."""
    _plan_matmul_cached.cache_clear()
    _plan_attention_cached.cache_clear()
    _plan_kv_pages_cached.cache_clear()
    _plan_shard_budget_cached.cache_clear()
    _MEASURED.clear()
