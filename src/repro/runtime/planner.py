"""Analytical block planner: per-shape (bm, bn, bk) / (bq, bkv) selection.

The paper's §3.1 soundness condition — loading must stay ahead of compute —
is evaluated analytically by ``core/pipeline.plan_matmul_blocks``; this
module turns it into the *execution plan* the kernels actually run with,
instead of the old one-size-fits-all ``DEFAULT_BM/BN/BK`` constants:

  * candidate blocks are filtered to exact divisors of (N, K) (and (Sq,
    Skv) for attention) so the Pallas grid tiles the problem with no
    remainder — M alone is padded by the ops wrapper;
  * the surviving candidate maximizing (pipelined, margin, -vmem) under the
    VMEM budget wins; plans are lru-cached per shape so planning is free
    after the first trace;
  * ``None`` means no legal blocking exists (ragged dims) and the caller
    falls back to the jnp reference path — exactly the old behavior, now in
    one place.

Overrides:
  REPRO_BLOCKS_MATMUL="bm,bn,bk"  pin matmul blocks (divisibility checked)
  REPRO_BLOCKS_ATTN="bq,bkv"      pin attention blocks
  REPRO_AUTOTUNE=1                measured autotuning: ops wrappers time the
                                  top analytical candidates on the real
                                  kernel and cache the winner per shape
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable, Optional

from repro.core.pipeline import HwSpec, TPU_V5E, plan_matmul_blocks

__all__ = [
    "MatmulBlocks", "AttentionBlocks", "plan_matmul", "plan_attention",
    "matmul_candidates", "autotune_enabled", "measured_best",
    "measured_plan", "clear_plan_cache", "DEFAULT_BM",
    "VMEM_BUDGET_FRACTION",
]

# bm candidate ceiling for tiny-M problems (M is padded to the chosen bm,
# so candidates above this only waste padding). This is the only default
# tile constant left in the tree — kernels take explicit blocks now.
DEFAULT_BM = 256

#: fraction of per-core VMEM a plan may claim (double buffers + scratch
#: accounting lives in core/pipeline._block_cost)
VMEM_BUDGET_FRACTION = 0.9

_BLOCK_CANDIDATES = (2048, 1024, 512, 384, 256, 128, 64, 32, 16, 8)


@dataclasses.dataclass(frozen=True)
class MatmulBlocks:
    bm: int
    bn: int
    bk: int
    pipelined: bool          # t_load <= t_compute (paper's §3.1 condition)
    margin: float            # compute/load ratio; >1 => DMA fully hidden
    vmem_bytes: int


@dataclasses.dataclass(frozen=True)
class AttentionBlocks:
    bq: int
    bkv: int
    pipelined: bool
    margin: float
    vmem_bytes: int


def _divisors(dim: int, *, even: bool = False) -> tuple[int, ...]:
    out = tuple(c for c in _BLOCK_CANDIDATES
                if c <= dim and dim % c == 0
                and (not even or c % 2 == 0))
    # the §3.1 margin is block-size-neutral along K (load and compute both
    # scale linearly), so an unfiltered search ties and the min-VMEM
    # tie-break degenerates to 8-wide tiles. Floor candidates at the MXU
    # tile (128) when the dim admits one — sub-MXU tiles waste the systolic
    # array no matter what the byte model says.
    if out:
        floor = min(128, max(out))
        out = tuple(c for c in out if c >= floor)
    return out


def matmul_candidates(m: int, k: int, n: int, *,
                      packed: bool = False) -> tuple:
    """(bm, bn, bk) candidate tuples under the divisibility rules the
    Pallas wrapper needs: bn | n, bk | k (bn even when int4-packed); bm is
    free (M is padded)."""
    bm_c = tuple(c for c in _BLOCK_CANDIDATES if c <= max(m, DEFAULT_BM))
    bn_c = _divisors(n, even=packed)
    bk_c = _divisors(k)
    return bm_c, bn_c, bk_c


def _env_override(var: str, n_fields: int) -> Optional[tuple[int, ...]]:
    raw = os.environ.get(var)
    if not raw:
        return None
    parts = tuple(int(p) for p in raw.replace(" ", "").split(","))
    if len(parts) != n_fields:
        raise ValueError(f"{var}={raw!r}: expected {n_fields} ints")
    return parts


@functools.lru_cache(maxsize=4096)
def _plan_matmul_cached(m: int, k: int, n: int, weight_bits: int,
                        act_bytes: int, packed: bool,
                        hw: HwSpec) -> Optional[MatmulBlocks]:
    bm_c, bn_c, bk_c = matmul_candidates(m, k, n, packed=packed)
    if not bn_c or not bk_c:
        return None                       # ragged dims: ref fallback
    plan = plan_matmul_blocks(m, n, k, weight_bits=weight_bits,
                              act_bytes=act_bytes, hw=hw,
                              candidates_m=bm_c, candidates_n=bn_c,
                              candidates_k=bk_c,
                              vmem_fraction=VMEM_BUDGET_FRACTION)
    # the tiny-problem fallback inside plan_matmul_blocks ignores the
    # candidate filter; re-check divisibility before trusting it
    if n % plan.bn or k % plan.bk or (packed and plan.bn % 2):
        return None
    return MatmulBlocks(plan.bm, plan.bn, plan.bk, plan.pipelined,
                        plan.margin, plan.vmem_bytes)


def plan_matmul(m: int, k: int, n: int, *, weight_bits: int = 16,
                act_bytes: int = 2, packed: bool = False,
                hw: HwSpec = TPU_V5E) -> Optional[MatmulBlocks]:
    """Blocks for x:(M,K) @ W:(K,N) with b-bit SPx weight codes, or None if
    no legal blocking exists (caller falls back to the ref path)."""
    pinned = _env_override("REPRO_BLOCKS_MATMUL", 3)
    if pinned is not None:
        bm, bn, bk = pinned
        if n % bn or k % bk or (packed and bn % 2):
            return None
        return MatmulBlocks(bm, bn, bk, False, 0.0, 0)
    return _plan_matmul_cached(m, k, n, weight_bits, act_bytes, packed, hw)


@functools.lru_cache(maxsize=4096)
def _plan_attention_cached(sq: int, skv: int, dh: int, act_bytes: int,
                           hw: HwSpec) -> Optional[AttentionBlocks]:
    best = None
    for bq in _divisors(sq):
        for bkv in _divisors(skv):
            # per inner grid step: stream the next (K, V) tile pair while
            # the MXU runs QK^T + PV on the current one (q stays resident
            # across the KV loop)
            t_load = 2 * bkv * dh * act_bytes / hw.hbm_bw
            t_compute = 4.0 * bq * bkv * dh / hw.peak_bf16_flops
            vmem = (2 * (bq * dh + 2 * bkv * dh) * act_bytes
                    + bq * dh * 4 + 2 * bq * 4)      # acc + (m, l) scratch
            if vmem > hw.vmem_bytes * VMEM_BUDGET_FRACTION:
                continue
            margin = t_compute / max(t_load, 1e-30)
            plan = AttentionBlocks(bq, bkv, t_load <= t_compute, margin,
                                   int(vmem))
            key = (plan.pipelined, plan.margin, -plan.vmem_bytes)
            if best is None or key > (best.pipelined, best.margin,
                                      -best.vmem_bytes):
                best = plan
    return best


def plan_attention(sq: int, skv: int, dh: int, *, act_bytes: int = 2,
                   hw: HwSpec = TPU_V5E) -> Optional[AttentionBlocks]:
    """(bq, bkv) for flash attention over (Sq, Skv, dh), or None when the
    sequence dims admit no candidate blocking (ref fallback)."""
    pinned = _env_override("REPRO_BLOCKS_ATTN", 2)
    if pinned is not None:
        bq, bkv = pinned
        if sq % bq or skv % bkv:
            return None
        return AttentionBlocks(bq, bkv, False, 0.0, 0)
    return _plan_attention_cached(sq, skv, dh, act_bytes, hw)


# ---------------------------------------------------------------------------
# Measured autotuning (env/flag-gated)
# ---------------------------------------------------------------------------

_MEASURED: dict = {}


def autotune_enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "").lower() in ("1", "true",
                                                            "measured")


def measured_plan(key):
    """Previously measured winner for this shape key, or None. Consulted at
    trace time too (shapes are concrete there), so a winner measured during
    an eager warm-up call applies to every later jitted step."""
    return _MEASURED.get(key)


def measured_best(key, plans, runner: Callable[[object], float]):
    """Time each candidate plan with ``runner`` (seconds per call on the
    real kernel + real arrays) and cache the winner per shape key. The ops
    wrappers call this only when ``autotune_enabled()``; the analytical
    plan always seeds the candidate list so measurement can only improve
    on it."""
    if key in _MEASURED:
        return _MEASURED[key]
    best, best_t = None, float("inf")
    for plan in plans:
        try:
            t = runner(plan)
        except Exception as e:     # candidate doesn't compile on this target
            print(f"[planner] autotune candidate {plan} failed: {e!r}")
            continue
        if t < best_t:
            best, best_t = plan, t
    if best is None:
        # nothing measured: return the analytical seed WITHOUT caching so a
        # transient failure doesn't pin a known-bad plan for the process
        return plans[0]
    _MEASURED[key] = best
    return best


def clear_plan_cache():
    _plan_matmul_cached.cache_clear()
    _plan_attention_cached.cache_clear()
    _MEASURED.clear()
