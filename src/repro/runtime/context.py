"""The frozen execution context threaded through every layer.

``Runtime`` is a frozen, hashable dataclass so it is a legal *static*
argument to ``jax.jit`` (``static_argnums``): two Runtimes with equal field
values hash and compare equal, so replacing one with an equal-valued copy
causes **zero** recompiles (see tests/test_runtime.py::test_no_retrace).
This replaces the old mutable knobs object in ``nn/layers.py`` whose
positional ``replace()`` silently dropped fields when the field list grew.

Field semantics are unchanged from the original object:

  impl             kernel impl: auto | pallas | interpret | ref (resolved
                   once through ``repro.runtime.registry``)
  q_chunk          query-chunk for the memory-bounded jnp attention path
  remat            none | full | dots
  mesh             jax Mesh or None (single device); Mesh is hashable
  decode_seq_axis  mesh axis for context-parallel decode
  data_axes        batch axes (tuple — kept hashable)
  model_axis       tensor/expert-parallel axis
  unroll           True removes every While loop (roofline cost variants
                   only — DESIGN.md §6)
  kv_quant         quantized KV cache: codebook codes + per-position scale
                   (EXPERIMENTS.md §Perf cell 1). The *level set* is chosen
                   by ``kv_scheme`` — plain int8 is the ``uniform8`` scheme,
                   not SPx; the non-uniform SPx options are ``sp2_8`` /
                   ``spx_8_x3`` (see core/spx.SCHEMES, docs/QUANTIZATION.md)
  kv_scheme        core/spx scheme name for the quantized KV cache (only
                   read when kv_quant is set; 8-bit code widths only)
  attn_cp          context-parallel prefill attention (§Perf cell 2)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

__all__ = ["Runtime"]


@dataclasses.dataclass(frozen=True)
class Runtime:
    impl: str = "auto"
    q_chunk: int = 1024
    remat: str = "none"
    mesh: Any = None
    decode_seq_axis: Optional[str] = None
    data_axes: tuple = ("data",)
    model_axis: Optional[str] = "model"
    unroll: bool = False
    kv_quant: bool = False
    kv_scheme: str = "uniform8"
    attn_cp: bool = False

    def __post_init__(self):
        # lists sneak in from argparse/config plumbing; tuples keep us
        # hashable (and therefore jit-static)
        if not isinstance(self.data_axes, tuple):
            object.__setattr__(self, "data_axes",
                               tuple(self.data_axes or ()))

    def replace(self, **kw) -> "Runtime":
        """Keyword-only field replacement (dataclasses.replace), immune to
        the field-order bugs of the old positional copy."""
        return dataclasses.replace(self, **kw)
