"""Unified execution-plan runtime (DESIGN.md §7).

Three pieces, co-designed the way RedMulE/FantastIC4 argue the win comes:

  * ``context.Runtime``   — frozen, hashable execution knobs; a legal
                            static jit argument (zero retrace on
                            equal-value replace)
  * ``registry``          — kernel dispatch table: (op, impl) -> entry,
                            resolved once per backend instead of per
                            callsite string matching
  * ``planner``           — analytical (bm, bn, bk)/(bq, bkv) selection
                            from core/pipeline's §3.1 load-vs-compute
                            model, lru-cached per shape, env-overridable,
                            with gated measured autotuning
"""
from . import planner, registry
from .context import Runtime
from .planner import (AttentionBlocks, KVPagePlan, MatmulBlocks,
                      plan_attention, plan_kv_pages, plan_matmul)
from .registry import (KernelEntry, KernelUnavailable, available_impls,
                       register, resolve)

__all__ = [
    "Runtime", "planner", "registry", "MatmulBlocks", "AttentionBlocks",
    "KVPagePlan", "plan_matmul", "plan_attention", "plan_kv_pages",
    "KernelEntry", "KernelUnavailable", "available_impls", "register",
    "resolve",
]
