"""Kernel registry: one dispatch table instead of per-callsite string checks.

Kernels register ``(op, impl)`` pairs with an availability predicate and a
priority; resolution happens **once** per (op, impl, backend) and is cached,
so the nn/ layers never re-derive "pallas on TPU, ref elsewhere" themselves.

  register(op, impl, priority=..., available=...)   — decorator
  resolve(op, impl="auto") -> KernelEntry           — cached resolution
  available_impls(op) -> tuple[str, ...]

``impl`` semantics (unchanged from the old kernels/ops.py dispatch):
  * "auto"      — highest-priority available impl (pallas on TPU, ref
                  elsewhere: pallas registers with a TPU-only predicate)
  * "pallas"    — compiled Mosaic kernel (TPU target)
  * "interpret" — pallas_call(interpret=True); tests validate the kernel
                  body bit-for-bit against the ref oracle on CPU
  * "ref"       — pure-jnp oracle

The built-in kernels live in ``repro.kernels.ops`` and register themselves
at import; ``resolve`` imports that module lazily so the registry package
itself stays dependency-free. Current built-in ops: ``spx_matmul``,
``flash_attention``, ``paged_attention`` (serving decode over the paged KV
cache — see docs/SERVING.md), ``paged_attention_quant`` (same, over
codes+scale quantized pools with fused codebook dequant —
docs/QUANTIZATION.md), and ``paged_decode_ragged`` /
``paged_decode_ragged_quant`` (the decode megakernel: one launch per
serving decode tick over a ragged (slot, attend_len) grid, covering plain
decode and the speculative verify window, with in-kernel LUT dequant for
quantized pools).
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
from typing import Callable

__all__ = ["KernelEntry", "KernelUnavailable", "register", "resolve",
           "available_impls", "registered_ops", "PRIORITY_ACCELERATOR",
           "PRIORITY_REFERENCE", "PRIORITY_DEBUG"]

#: priority tiers for "auto" resolution (highest available wins; explicitly
#: requested impls bypass priority entirely). Registrations should use
#: these rather than raw ints so the ordering lives in one place.
PRIORITY_ACCELERATOR = 100      # compiled device kernel (pallas)
PRIORITY_REFERENCE = 10         # pure-jnp oracle
PRIORITY_DEBUG = 1              # interpret-mode kernel (slow, CPU)


class KernelUnavailable(LookupError):
    pass


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    op: str
    impl: str
    fn: Callable
    available: Callable[[], bool]
    priority: int


_REGISTRY: dict[tuple[str, str], KernelEntry] = {}
_BUILTINS_LOADED = False


def register(op: str, impl: str, *, priority: int = 0,
             available: Callable[[], bool] = lambda: True):
    """Decorator: register ``fn`` as the ``impl`` implementation of ``op``.

    ``available`` is evaluated at resolve time (per backend), not at import:
    the pallas entries register everywhere but only resolve on TPU.
    Registering invalidates the resolution cache, so a late registration
    (e.g. a test stubbing an op) takes effect on the next ``resolve``.
    ``priority`` only orders ``"auto"`` resolution — use the
    ``PRIORITY_*`` tiers above rather than raw ints.
    """
    def deco(fn):
        _REGISTRY[(op, impl)] = KernelEntry(op, impl, fn, available, priority)
        _resolve_cached.cache_clear()
        return fn
    return deco


def _ensure_builtins():
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        importlib.import_module("repro.kernels.ops")
        # only latch on success so a transient import failure surfaces on
        # every call instead of decaying into "no impl registered"
        _BUILTINS_LOADED = True


def _backend() -> str:
    import jax
    return jax.default_backend()


@functools.lru_cache(maxsize=None)
def _resolve_cached(op: str, impl: str, backend: str) -> KernelEntry:
    del backend  # part of the cache key: availability is backend-dependent
    if impl != "auto":
        entry = _REGISTRY.get((op, impl))
        if entry is None:
            raise KernelUnavailable(
                f"no impl {impl!r} registered for op {op!r}; "
                f"have {available_impls(op)}")
        return entry
    candidates = [e for (o, _), e in _REGISTRY.items()
                  if o == op and e.available()]
    if not candidates:
        raise KernelUnavailable(f"no available impl for op {op!r}")
    return max(candidates, key=lambda e: e.priority)


def resolve(op: str, impl: str = "auto") -> KernelEntry:
    """Resolve ``(op, impl)`` to a registered entry.

    Cached per (op, impl, backend) for the process lifetime — availability
    predicates run once per backend, not per call, so layers may resolve
    inside jitted code at zero cost. Raises ``KernelUnavailable`` for an
    unknown impl (listing what exists) or when no registered impl's
    availability predicate passes for ``"auto"``.
    """
    _ensure_builtins()
    return _resolve_cached(op, impl, _backend())


def available_impls(op: str) -> tuple[str, ...]:
    """Impl names whose availability predicate passes right now, sorted.
    Uncached — predicates are re-evaluated on every call (cheap; used for
    error messages and diagnostics, not on hot paths)."""
    _ensure_builtins()
    return tuple(sorted(i for (o, i), e in _REGISTRY.items()
                        if o == op and e.available()))


def registered_ops() -> tuple[str, ...]:
    """All op names with at least one registered impl (available or not),
    sorted. Uncached."""
    _ensure_builtins()
    return tuple(sorted({o for (o, _) in _REGISTRY}))
