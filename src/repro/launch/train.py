"""End-to-end training driver.

Small-scale real run (CPU/laptop):
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --reduced \
      --steps 100 --batch 8 --seq 128

On real hardware the same driver takes ``--mesh-data/--mesh-model`` to build
a device mesh and shard via the production policy. Fault tolerance:
``--ckpt-dir`` enables periodic checkpoints + resume; ``--kill-at-step``
injects a failure to exercise restart; ``--compress-grads sp2_8`` enables
SPx gradient compression with error feedback (cross-pod DP reduction).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.tokens import TokenStream
from repro.models import encdec as ed
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.training import (GradCompressor, TrainConfig, TrainLoop,
                            make_optimizer)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving small config (CPU-runnable)")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["sgd", "adamw", "adamw_q8"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kill-at-step", type=int, default=None)
    ap.add_argument("--compress-grads", default=None,
                    help="SPx scheme for gradient compression, e.g. sp2_8")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, d_model=args.d_model,
                      n_layers=args.layers or None)
    rt = Runtime(impl="auto", q_chunk=min(1024, args.seq))

    data = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    loss_mod = ed.encdec_loss if cfg.enc_dec else lm_mod.lm_loss

    def loss_fn(params, batch, rt):
        if cfg.enc_dec and "frames" not in batch:
            b = batch["tokens"].shape[0]
            batch = dict(batch, frames=jnp.zeros(
                (b, cfg.enc_seq_len, cfg.d_model), jnp.float32))
        loss, metrics = loss_mod(params, batch, cfg, rt)
        return loss, metrics

    def init_params():
        key = jax.random.PRNGKey(args.seed)
        if cfg.enc_dec:
            return ed.encdec_init(key, cfg)
        return lm_mod.lm_init(key, cfg)

    comp = GradCompressor(args.compress_grads) if args.compress_grads else None
    tc = TrainConfig(max_steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, log_every=10,
                     accum_steps=args.accum, kill_at_step=args.kill_at_step,
                     compress_grads=args.compress_grads)
    loop = TrainLoop(loss_fn, make_optimizer(args.optimizer, lr=args.lr),
                     init_params, iter(data), tc, compressor=comp, rt=rt)
    try:
        params, hist = loop.run()
        uniform = float(jnp.log(jnp.float32(cfg.vocab_size)))
        print(f"[train] done: {hist[-1]['loss']:.4f} final loss "
              f"(uniform={uniform:.2f})")
        return hist
    finally:
        data.close()


if __name__ == "__main__":
    main()
