"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes (16x16 single-pod; 2x16x16 multi-pod), print
memory_analysis / cost_analysis, and dump a JSON artifact per cell that the
roofline harness consumes.

The production meshes need 512 devices; ``main()`` forces them via
``launch/hostdev`` *at entry*, not at import — importing this module for
``parse_collectives`` must not poison the importer's device topology.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import os

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import assigned_archs, get_config
from repro.configs.base import LM_SHAPES
from repro.compat import cost_analysis_dict
from repro.launch.mesh import ambient_mesh, make_production_mesh
from repro.launch.steps import build_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts")

_COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "s4": 0.5, "u4": 0.5, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(type_str: str) -> float:
    """'bf16[16,4096,512]{...}' -> bytes."""
    m = re.match(r"([a-z0-9]+)\[([\d,]*)\]", type_str)
    if not m:
        return 0.0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Per-computation collective byte totals + while-loop trip counts.

    Returns {'computations': {name: {'bytes': b, 'ops': n}},
             'whiles': [{'body': name, 'trip_count': t or None}]}
    XLA cost analysis counts While bodies ONCE; the roofline harness
    multiplies each body's collective bytes by its trip count.
    """
    comps: dict = {}
    whiles = []
    cur = None
    consts: dict = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->", stripped)
        if m and stripped.endswith("{"):
            cur = m.group(1)
            comps[cur] = {"bytes": 0.0, "ops": 0}
            continue
        if stripped.startswith("}"):
            continue
        if cur is None:
            continue
        cm = re.match(r"%?([\w\.\-]+)\s*=\s*([a-z0-9]+\[[\d,]*\])"
                      r"[^=]*constant\((\d+)\)", stripped)
        if cm:
            consts[(cur, cm.group(1))] = int(cm.group(3))
        wm = re.search(r"=\s*\([^)]*\)\s*while\(|=\s*[a-z0-9]+\[[\d,]*\][^=]*"
                       r"while\(", stripped)
        if wm:
            bm = re.search(r"body=%?([\w\.\-]+)", stripped)
            if bm:
                whiles.append({"body": bm.group(1), "parent": cur,
                               "trip_count": None})
        if _COLLECTIVE_RE.search(stripped):
            if "-done" in stripped.split(" = ")[0]:
                continue  # matching -start already counted
            tm = re.search(r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\]\S*))",
                           stripped)
            if not tm:
                continue
            tstr = tm.group(1)
            if tstr.startswith("("):
                total = sum(_shape_bytes(t.strip())
                            for t in tstr[1:-1].split(",") if "[" in t)
            else:
                total = _shape_bytes(tstr)
            comps[cur]["bytes"] += total
            comps[cur]["ops"] += 1
    # trip counts: find compare-vs-constant in condition computations is
    # brittle; instead the harness passes known trip counts per while body
    # (layer periods, loss chunks, attention chunks) by body name matching.
    return {"computations": comps, "whiles": whiles}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             artifact_dir: str, verbose: bool = True,
             extra_kw: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = next(s for s in LM_SHAPES if s.name == shape_name)
    skip = None
    for s, why in cfg.shapes():
        if s.name == shape_name:
            skip = why
    if skip:
        return {"arch": arch, "shape": shape_name, "status": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.monotonic()
    with ambient_mesh(mesh):
        bundle = build_step(cfg, shape, mesh, **(extra_kw or {}))
        jfn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings,
                      donate_argnums=bundle.donate_argnums)
        lowered = jfn.lower(*bundle.args)
        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    n_chips = 512 if multi_pod else 256
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "status": "ok",
        "meta": bundle.meta,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_bytes": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      + mem.output_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "cost": {"flops": cost.get("flops", 0.0),
                 "bytes": cost.get("bytes accessed", 0.0),
                 "transcendentals": cost.get("transcendentals", 0.0)},
        "collectives": coll,
        "model_flops_dense": 6 * cfg.param_count_estimate()
        * shape.global_batch * shape.seq_len,
        "model_flops_active": 6 * cfg.active_param_count_estimate()
        * shape.global_batch * shape.seq_len,
        "param_count": cfg.param_count_estimate(),
        "active_param_count": cfg.active_param_count_estimate(),
    }
    if verbose:
        peak_gb = result["memory"]["peak_per_device_bytes"] / 1e9
        print(f"[{arch} x {shape_name} x {result['mesh']}] "
              f"compile={t_compile:.0f}s peak/device={peak_gb:.2f}GB "
              f"flops={result['cost']['flops']:.3e} "
              f"coll_ops={sum(c['ops'] for c in coll['computations'].values())}")
        print("  memory_analysis:", mem)
    fname = f"dryrun_{arch.replace('.', '_')}_{shape_name}" \
            f"_{'multi' if multi_pod else 'single'}.json"
    os.makedirs(artifact_dir, exist_ok=True)
    with open(os.path.join(artifact_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    # the production meshes want 512 devices; forcing them here (before
    # the first jax computation initializes the backend) keeps the flag
    # out of importers of this module
    from repro.launch.hostdev import set_host_device_count
    set_host_device_count(512)
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--artifact-dir",
                    default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    cells = []
    archs = assigned_archs() if (args.all or not args.arch) else [args.arch]
    for a in archs:
        cfg = get_config(a)
        for s, why in cfg.shapes():
            if args.shape and s.name != args.shape:
                continue
            cells.append((a, s.name))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for multi in meshes:
        for a, sname in cells:
            try:
                run_cell(a, sname, multi_pod=multi,
                         artifact_dir=args.artifact_dir)
            except Exception as e:
                traceback.print_exc()
                failures.append((a, sname, multi, str(e)[:200]))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} cells compiled OK")


if __name__ == "__main__":
    main()
