"""Step-function builders for training and serving — shared by the dry-run,
the roofline harness, and the real drivers. Everything is built from
ShapeDtypeStructs (jax.eval_shape) so a 1T-param config costs no memory
until a real driver decides to materialize it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as ed
from repro.models import lm as lm_mod
from repro.nn.layers import quantize_params
from repro.runtime import Runtime
from repro.sharding import ShardingPolicy, make_policy
from repro.training.optimizer import clip_by_global_norm, make_optimizer

__all__ = ["StepBundle", "build_step", "make_runtime"]

GIANT_PARAMS = 30e9    # above this: SPx-quantized (8-bit) AdamW moments
SERVE_SCHEME = "sp2_4"      # the paper's 4-bit SP2 for weight-only serving


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    args: tuple                  # ShapeDtypeStructs with shardings attached
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple
    meta: dict


def make_runtime(cfg: ArchConfig, mesh: Mesh | None, shape: ShapeConfig | None,
                 *, impl: str = "ref", remat: str = "none",
                 unroll: bool = False) -> Runtime:
    data_axes: tuple = ()
    if mesh is not None:
        axes = dict(mesh.shape)
        data_axes = tuple(a for a in ("pod", "data") if a in axes)
        if shape is not None:
            import numpy as np
            n_data = int(np.prod([axes[a] for a in data_axes])) or 1
            if shape.global_batch % n_data:
                # long_500k (B=1): batch replicates over data axes
                data_axes = tuple(a for a in data_axes
                                  if shape.global_batch % axes[a] == 0)
    return Runtime(impl=impl, q_chunk=1024, remat=remat, mesh=mesh,
                   decode_seq_axis="model" if mesh is not None else None,
                   data_axes=data_axes, model_axis="model", unroll=unroll)


def _sds_with_sharding(tree_sds, ns_tree):
    return jax.tree_util.tree_map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        tree_sds, ns_tree)


def _params_sds(cfg: ArchConfig, dtype, quantized: bool):
    def init():
        key = jax.random.PRNGKey(0)
        if cfg.enc_dec:
            p = ed.encdec_init(key, cfg, dtype=dtype)
        else:
            p = lm_mod.lm_init(key, cfg, dtype=dtype)
        if quantized:
            p = quantize_params(p, SERVE_SCHEME)
        return p
    return jax.eval_shape(init)


def _batch_sds(cfg: ArchConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.mrope_sections is not None:
        out["positions"] = jax.ShapeDtypeStruct((b, 3, s), jnp.int32)
    if cfg.enc_dec:
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
    return out


def _caches_sds(cfg: ArchConfig, b: int, s: int, kv_quant: bool = False):
    if cfg.enc_dec:
        return jax.eval_shape(
            lambda: ed.encdec_init_caches(cfg, b, s, dtype=jnp.bfloat16,
                                          kv_quant=kv_quant))
    return jax.eval_shape(
        lambda: lm_mod.init_caches(cfg, b, s, dtype=jnp.bfloat16,
                                   kv_quant=kv_quant))


def _metric_specs(mesh, tree):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def build_train_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
                     impl: str = "ref", remat: str = "full",
                     optimizer: str | None = None,
                     accum_steps: int | None = None, unroll: bool = False,
                     dtype=jnp.bfloat16) -> StepBundle:
    giant = cfg.param_count_estimate() > GIANT_PARAMS
    opt_name = optimizer or ("adamw_q8" if giant else "adamw")
    if accum_steps is None:
        # microbatching for the giants: activations scale with B/accum, and
        # the backward of microbatch i overlaps the DP reduce of i-1
        accum_steps = 8 if giant else 1
    acc_dtype = jnp.bfloat16 if giant else jnp.float32
    if unroll:
        accum_steps = 1          # cost variants measure one full batch
        remat = "none"
    opt = make_optimizer(opt_name, lr=1e-4, weight_decay=0.01)
    # parallelism selection (EXPERIMENTS.md §Perf iter 6): pure-FSDP beats
    # TP+SP for <=30B trains whenever the batch covers every chip — no
    # activation collectives, only per-layer param gathers
    import numpy as _np
    n_chips = int(_np.prod(list(dict(mesh.shape).values())))
    parallelism = ("fsdp" if (not giant
                              and shape.global_batch % n_chips == 0)
                   else "tp")
    policy = make_policy(cfg, mesh, parallelism=parallelism)
    rt = make_runtime(cfg, mesh, shape, impl=impl, remat=remat,
                      unroll=unroll)
    rt = rt.replace(model_axis=policy.model_axis,
                    data_axes=policy.data_axes)
    loss_fn = ed.encdec_loss if cfg.enc_dec else lm_mod.lm_loss

    def train_step(params, opt_state, batch):
        def lf(p, b):
            return loss_fn(p, b, cfg, rt)

        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
        else:
            def micro(acc, mb):
                (l, m), g = jax.value_and_grad(lf, has_aux=True)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b2: a + b2.astype(acc_dtype), acc, g)
                return acc, (l, m)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            grads, (losses, ms) = jax.lax.scan(micro, zero, mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree_util.tree_map(jnp.mean, ms)

        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(params, grads, opt_state)
        metrics = dict(metrics, loss=loss, gnorm=gnorm)
        return params, opt_state, metrics

    params_sds = _params_sds(cfg, dtype, quantized=False)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    batch_sds = _batch_sds(cfg, shape)

    p_ns = policy.named(policy.param_specs(params_sds))
    o_ns = policy.named(policy.opt_specs(params_sds, opt_sds))
    b_spec = {k: NamedSharding(mesh, policy.batch_spec(v.shape[0],
                                                       len(v.shape) - 1))
              for k, v in batch_sds.items()}
    metrics_sds = {"ce": 0.0, "loss": 0.0, "gnorm": 0.0}
    if not cfg.enc_dec:
        metrics_sds["aux"] = 0.0
    metrics_sds["z"] = 0.0
    m_ns = _metric_specs(mesh, metrics_sds)

    args = (_sds_with_sharding(params_sds, p_ns),
            _sds_with_sharding(opt_sds, o_ns),
            _sds_with_sharding(batch_sds, b_spec))
    return StepBundle(
        fn=train_step, args=args,
        in_shardings=(p_ns, o_ns, b_spec),
        out_shardings=(p_ns, o_ns, m_ns),
        donate_argnums=(0, 1),
        meta={"kind": "train", "optimizer": opt_name, "fsdp": policy.fsdp,
              "parallelism": parallelism, "remat": remat})


# ---------------------------------------------------------------------------
# Serve: prefill / decode
# ---------------------------------------------------------------------------

def build_serve_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
                     impl: str = "ref", quantized: bool = True,
                     kv_quant: bool = False, unroll: bool = False,
                     prefill_cp: bool | None = None,
                     dtype=jnp.bfloat16) -> StepBundle:
    rt = make_runtime(cfg, mesh, shape, impl=impl, unroll=unroll)
    rt = rt.replace(kv_quant=kv_quant)
    b, s = shape.global_batch, shape.seq_len
    params_sds = _params_sds(cfg, dtype, quantized=quantized)
    caches_sds = _caches_sds(cfg, b, s, kv_quant=kv_quant)

    # context-parallel prefill (§Perf cell 2): sequence-sharded activations
    # + FSDP (gathered) weights + KV-gather attention — replaces the TP/SP
    # activation gathers. On by default where it applies (long prefill of
    # non-giant archs whose dims divide the axes).
    if prefill_cp is None:
        prefill_cp = (shape.kind == "prefill"
                      and cfg.param_count_estimate() <= 30e9
                      and s % 16 == 0 and b % 16 == 0
                      and not cfg.enc_dec)
    if shape.kind == "prefill" and prefill_cp:
        policy = make_policy(cfg, mesh, parallelism="replicated")
        rt = rt.replace(attn_cp=True, model_axis="model",
                        data_axes=tuple(a for a in ("pod", "data")
                                        if a in dict(mesh.shape)))
    else:
        policy = make_policy(cfg, mesh)
    p_ns = policy.named(policy.param_specs(params_sds))
    c_ns = policy.named(policy.cache_specs(caches_sds))
    logit_ns = NamedSharding(mesh, policy.batch_spec(b, 1))

    if shape.kind == "prefill":
        if cfg.enc_dec:
            def step(params, frames, tokens, caches):
                return ed.encdec_prefill(params, frames, tokens, caches, cfg,
                                         rt)
            frames_sds = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)
            f_ns = NamedSharding(mesh, policy.batch_spec(b, 2))
            tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
            t_ns = NamedSharding(mesh, policy.batch_spec(b, 1))
            args = (_sds_with_sharding(params_sds, p_ns),
                    jax.ShapeDtypeStruct(frames_sds.shape, frames_sds.dtype,
                                         sharding=f_ns),
                    jax.ShapeDtypeStruct(tok_sds.shape, tok_sds.dtype,
                                         sharding=t_ns),
                    _sds_with_sharding(caches_sds, c_ns))
            return StepBundle(step, args,
                              in_shardings=(p_ns, f_ns, t_ns, c_ns),
                              out_shardings=(logit_ns, c_ns),
                              donate_argnums=(3,),
                              meta={"kind": "prefill", "quantized": quantized})

        def step(params, tokens, caches):
            extra = {}
            if cfg.mrope_sections is not None:
                bb, ss = tokens.shape
                pos = jnp.broadcast_to(jnp.arange(ss, dtype=jnp.int32),
                                       (bb, ss))
                extra["positions"] = jnp.broadcast_to(pos[:, None, :],
                                                      (bb, 3, ss))
            return lm_mod.lm_prefill(params, tokens, caches, cfg, rt,
                                     positions=extra.get("positions"))
        tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
        t_ns = NamedSharding(mesh, policy.batch_spec(b, 1))
        args = (_sds_with_sharding(params_sds, p_ns),
                jax.ShapeDtypeStruct(tok_sds.shape, tok_sds.dtype,
                                     sharding=t_ns),
                _sds_with_sharding(caches_sds, c_ns))
        return StepBundle(step, args,
                          in_shardings=(p_ns, t_ns, c_ns),
                          out_shardings=(logit_ns, c_ns),
                          donate_argnums=(2,),
                          meta={"kind": "prefill", "quantized": quantized,
                                "prefill_cp": prefill_cp})

    # decode: one token against a seq_len cache
    if cfg.enc_dec:
        def step(params, token, pos, caches):
            return ed.encdec_decode_step(params, token, pos, caches, cfg, rt)
    else:
        def step(params, token, pos, caches):
            return lm_mod.lm_decode_step(params, token, pos, caches, cfg, rt)
    tok_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
    t_ns = NamedSharding(mesh, policy.batch_spec(b, 0))
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    pos_ns = NamedSharding(mesh, P())
    args = (_sds_with_sharding(params_sds, p_ns),
            jax.ShapeDtypeStruct(tok_sds.shape, tok_sds.dtype, sharding=t_ns),
            jax.ShapeDtypeStruct(pos_sds.shape, pos_sds.dtype,
                                 sharding=pos_ns),
            _sds_with_sharding(caches_sds, c_ns))
    return StepBundle(step, args,
                      in_shardings=(p_ns, t_ns, pos_ns, c_ns),
                      out_shardings=(logit_ns, c_ns),
                      donate_argnums=(3,),
                      meta={"kind": "decode", "quantized": quantized,
                            "kv_quant": kv_quant})


def build_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               **kw) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, **kw)
    return build_serve_step(cfg, shape, mesh, **kw)
