"""Serving driver: spin up the batched engine with SPx-quantized weights and
run a synthetic request workload, reporting latency/throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 16 --scheme sp2_4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--scheme", default="sp2_4",
                    help="SPx scheme for weights; 'none' = dense bf16")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.enc_dec:
        raise SystemExit("serve driver targets decoder-only archs")

    params = lm_mod.lm_init(jax.random.PRNGKey(args.seed), cfg)
    scheme = None if args.scheme == "none" else args.scheme
    eng = ServeEngine(params, cfg, batch_slots=args.slots,
                      max_seq=args.max_seq, quantize=scheme,
                      rt=Runtime(impl="auto", q_chunk=256))

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, args.max_seq // 4))
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, plen)
                           .astype(np.int32),
                           max_new_tokens=args.new_tokens))
    done = eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.output) for r in done)
    ttfts = [r.t_first_token - r.t_enqueue for r in done]
    print(f"[serve] {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s), median TTFT {np.median(ttfts)*1e3:.0f}ms"
          f" scheme={scheme}")
    return done


if __name__ == "__main__":
    main()
