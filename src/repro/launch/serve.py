"""Serving driver: spin up the batched engine with SPx-quantized weights and
run a synthetic request workload, reporting latency/throughput/occupancy.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --requests 16 --scheme sp2_4 --kv-layout paged \
      --kv-quant --kv-scheme spx_8_x3

--arch accepts every bundled config, not just attention-only decoders:
SSM (xlstm-350m), hybrid (jamba-1.5-large-398b) and M-RoPE
(qwen2-vl-2b) configs serve through the unified state cache's slab
region, and enc-dec (whisper-small) runs with synthetic input frames —
two distinct inputs alternate across requests, so identical inputs
share one encoder pass through the cross-KV region (docs/SERVING.md,
"The unified state cache").

Weight quantization (--scheme) and KV-cache quantization (--kv-quant +
--kv-scheme, uniform8 baseline or non-uniform SPx) are independent axes;
both compose with either KV layout — see docs/QUANTIZATION.md.

--prefix-cache turns on shared-prefix KV page reuse: requests whose
prompts share a page-aligned prefix (a common system prompt) map the same
physical pages instead of re-prefilling them — docs/SERVING.md.

--spec-decode turns on prompt-lookup speculative decoding (paged layout):
an n-gram drafter proposes up to --spec-k tokens per decode tick and one
verify pass scores the whole window, so repetitive outputs cost fewer
model calls per token — docs/SERVING.md.

--scheduler picks the admission policy: 'cb' (continuous batching —
priority admission with preemption + KV page offload to a host tier,
the paged default) or 'fifo' (the synchronous head-blocks-queue
baseline). --host-pages bounds the offload tier, --prefix-cache-pages
bounds the cached-free prefix index (LRU eviction) — docs/SERVING.md.

--shards runs the engine tensor-parallel over a ``model`` mesh axis
(head-sharded KV pools, replicated block tables); --replicas stacks
data-parallel engine replicas behind a least-loaded router. On CPU,
force host devices first: XLA_FLAGS=--xla_force_host_platform_device_count=8
(repro.launch.hostdev) — docs/SERVING.md, "Sharded serving".

Env knobs that reach serving: REPRO_PAGE_SIZE (tokens per KV page),
REPRO_PREFILL_CHUNK (chunked-prefill length), REPRO_PREFIX_CACHE=1
(prefix cache default), REPRO_SPEC_K=N (speculative decoding default +
window), REPRO_SCHEDULER / REPRO_HOST_PAGES / REPRO_PREFIX_CACHE_PAGES
(scheduler + two-tier pool defaults), REPRO_SHARDS / REPRO_REPLICAS
(parallelism defaults), REPRO_BLOCKS_* / REPRO_AUTOTUNE (kernel tiles)
— all resolved in one place, ServeConfig.resolve() (docs/SERVING.md).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import spx
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving import ReplicaRouter
from repro.serving.engine import Request, ServeConfig, ServeEngine


def _run_streaming(eng, reqs, arrival_s: float):
    """Asyncio front-end over the tick-driven engine: one driver task
    steps the engine whenever it has work, a submitter feeds requests in
    over time (arrival overlaps compute), and one consumer per request
    drains ``async for tok in eng.stream(rid)`` as tokens are emitted —
    all on one event loop, no threads. Returns the finished list plus a
    per-rid monotonic stamp of the first *delivered* token, the
    user-visible TTFT the batch path cannot measure."""
    delivered: dict[int, float] = {}

    async def consume(req):
        async for _tok in eng.stream(req.rid):
            delivered.setdefault(req.rid, time.monotonic())

    async def submit_all(consumers):
        for req in reqs:
            eng.submit(req)
            consumers.append(asyncio.ensure_future(consume(req)))
            await asyncio.sleep(arrival_s)

    async def amain():
        consumers: list = []
        sub = asyncio.ensure_future(submit_all(consumers))
        # tick while anything is arriving or in flight, yielding after
        # every tick so consumers drain the tokens it just emitted
        while not sub.done() or eng.has_work():
            if eng.has_work():
                eng.step()
                await asyncio.sleep(0)
            else:
                await asyncio.sleep(arrival_s / 4)
        await sub
        await asyncio.gather(*consumers)

    asyncio.run(amain())
    return list(eng.finished), delivered


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--scheme", default="sp2_4",
                    help="SPx scheme for weights; 'none' = dense bf16")
    ap.add_argument("--kv-layout", default="auto",
                    choices=("auto", "paged", "dense"),
                    help="paged = block-table KV pool + chunked prefill")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page (default: planner-chosen)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="KV pool size in pages (default: dense-equivalent)")
    ap.add_argument("--prefill-chunk", type=int, default=None)
    ap.add_argument("--prefix-cache", action="store_true", default=None,
                    help="share page-aligned prompt-prefix KV pages across "
                         "requests (paged layout only; REPRO_PREFIX_CACHE=1 "
                         "sets the default)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend the same N-token system prompt to every "
                         "synthetic request (exercises --prefix-cache)")
    ap.add_argument("--spec-decode", action="store_true", default=None,
                    help="prompt-lookup speculative decoding (paged layout "
                         "only; REPRO_SPEC_K=N sets the default)")
    ap.add_argument("--spec-k", type=int, default=None, metavar="K",
                    help="draft window for --spec-decode (default 4; "
                         "passing it alone implies --spec-decode)")
    fg = ap.add_mutually_exclusive_group()
    fg.add_argument("--fused-decode", dest="fused_decode",
                    action="store_true", default=None,
                    help="ragged decode megakernel: one attention launch "
                         "per decode tick (paged layout; default ON, "
                         "REPRO_FUSED_DECODE=0 flips the default)")
    fg.add_argument("--no-fused-decode", dest="fused_decode",
                    action="store_false",
                    help="per-call paged-attention kernels + page-gather "
                         "verify (the pre-megakernel decode path)")
    ap.add_argument("--scheduler", default=None, choices=("fifo", "cb"),
                    help="admission policy: cb = continuous batching with "
                         "priority preemption + KV offload (paged default), "
                         "fifo = synchronous head-blocks-queue baseline "
                         "(REPRO_SCHEDULER sets the default)")
    ap.add_argument("--host-pages", type=int, default=None, metavar="N",
                    help="host offload tier capacity in pages (paged "
                         "layout; default unbounded, REPRO_HOST_PAGES "
                         "sets the default)")
    ap.add_argument("--prefix-cache-pages", type=int, default=None,
                    metavar="N",
                    help="cached-free prefix index budget in pages — LRU "
                         "eviction past it (default unbounded, "
                         "REPRO_PREFIX_CACHE_PAGES sets the default)")
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="tensor-parallel shards over the 'model' mesh axis "
                         "(paged layout; needs N devices — on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count; "
                         "REPRO_SHARDS sets the default)")
    ap.add_argument("--replicas", type=int, default=None, metavar="N",
                    help="data-parallel engine replicas behind a "
                         "least-loaded router, each with a per-replica "
                         "page budget (REPRO_REPLICAS sets the default)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="quantize the KV cache to codes+scale pages")
    ap.add_argument("--kv-scheme", default="spx_8_x3",
                    choices=sorted(s for s in spx.SCHEMES
                                   if spx.code_width(
                                       spx.scheme_levels(s)) == 8),
                    help="level set for --kv-quant (8-bit-code schemes)")
    ap.add_argument("--kv-dtype", default="f32", choices=("f32", "bf16"),
                    help="unquantized KV cache element dtype")
    ap.add_argument("--stream", action="store_true",
                    help="asyncio front-end: request arrival overlaps "
                         "engine ticks and each request's tokens are "
                         "consumed as they are emitted (async for over "
                         "engine.stream(rid)) — TTFT becomes time to "
                         "first *delivered* token. docs/SERVING.md, "
                         "'Streaming delivery and cancellation'.")
    ap.add_argument("--arrival-ms", type=float, default=0.0, metavar="MS",
                    help="gap between request arrivals under --stream "
                         "(0 = back-to-back, still interleaved with ticks)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    # the driver serves every bundled architecture: decoder-only configs
    # (dense/MoE/SSM/hybrid/M-RoPE) through the LM assembly, enc-dec
    # through the encoder-decoder assembly with synthetic input frames —
    # two distinct inputs alternating across requests, so the state
    # cache's shared cross-KV region sees hits (docs/SERVING.md)
    if cfg.enc_dec:
        params = encdec_mod.encdec_init(jax.random.PRNGKey(args.seed), cfg)
        fr_rng = np.random.default_rng(args.seed + 1)
        frame_sets = fr_rng.standard_normal(
            (2, cfg.enc_seq_len, cfg.d_model)).astype(np.float32)
    else:
        params = lm_mod.lm_init(jax.random.PRNGKey(args.seed), cfg)
        frame_sets = None
    scheme = None if args.scheme == "none" else args.scheme
    rt = Runtime(impl="auto", q_chunk=256, kv_quant=args.kv_quant,
                 kv_scheme=args.kv_scheme)
    sconf = ServeConfig(
        batch_slots=args.slots, max_seq=args.max_seq, quantize=scheme,
        kv_layout=args.kv_layout, page_size=args.page_size,
        pool_pages=args.pool_pages, prefill_chunk=args.prefill_chunk,
        kv_cache_dtype=(jnp.bfloat16 if args.kv_dtype == "bf16"
                        else jnp.float32),
        prefix_cache=args.prefix_cache,
        spec_decode=args.spec_decode, spec_k=args.spec_k,
        fused_decode=args.fused_decode,
        scheduler=args.scheduler, host_pages=args.host_pages,
        prefix_cache_pages=args.prefix_cache_pages,
        shards=args.shards, replicas=args.replicas).resolve(cfg)
    if sconf.replicas > 1:
        eng = ReplicaRouter(params, cfg, sconf, rt=rt)
    else:
        eng = ServeEngine(params, cfg, sconf, rt=rt)

    rng = np.random.default_rng(args.seed)
    sys_prompt = (rng.integers(0, cfg.vocab_size, args.shared_prefix)
                  .astype(np.int32))
    # each request must fit shared prefix + tail + new tokens in max_seq
    tail_cap = args.max_seq - args.shared_prefix - args.new_tokens
    if tail_cap < 2:
        raise SystemExit(
            f"--shared-prefix {args.shared_prefix} leaves no room for a "
            f"prompt tail (max-seq {args.max_seq}, new-tokens "
            f"{args.new_tokens})")
    hi = max(2, min(args.max_seq // 4, tail_cap))
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(min(4, hi - 1), hi))
        prompt = np.concatenate(
            [sys_prompt,
             rng.integers(0, cfg.vocab_size, plen).astype(np.int32)])
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=args.new_tokens,
                            frames=(None if frame_sets is None
                                    else frame_sets[i % 2])))
    t0 = time.monotonic()
    if args.stream:
        done, delivered = _run_streaming(eng, reqs,
                                         args.arrival_ms / 1e3)
    else:
        for req in reqs:
            eng.submit(req)
        done = eng.run()
    dt = time.monotonic() - t0
    n_tok = sum(len(r.output) for r in done)
    m = eng.metrics()
    # router metrics carry fleet sums; per-engine facts (layout, dtype,
    # pool geometry) live in the untouched per-replica dicts
    m0 = m["per_replica"][0] if sconf.replicas > 1 else m
    print(f"[serve] {len(done)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s), median TTFT {m['ttft_p50_ms']:.0f}ms "
          f"scheme={scheme} layout={m0['kv_layout']} "
          f"kv={m0['kv_scheme']}/{m0['kv_cache_dtype']}")
    if sconf.replicas > 1:
        print(f"[serve] router: {m['replicas']} replicas x {m['shards']} "
              f"shard(s), finished per replica "
              f"{m['requests_per_replica']}, fleet peak KV "
              f"{m['peak_kv_bytes'] / 2**20:.2f} MiB")
    if args.stream:
        sttft = sorted(delivered[r.rid] - r.t_enqueue for r in done)
        print(f"[serve] streaming: delivered TTFT p50 "
              f"{1e3 * sttft[len(sttft) // 2]:.0f}ms over "
              f"{len(done)} consumers (whole-request latency p50 "
              f"{m['latency_p50_ms']:.0f}ms)")
    if sconf.replicas == 1 and m["kv_layout"] == "paged":
        print(f"[serve] pages: {m['n_pages']} x {m['page_size']} tok, "
              f"occupancy mean {m['occupancy_mean']:.2f} / "
              f"peak {m['occupancy_peak']:.2f}, "
              f"peak KV {m['peak_kv_bytes'] / 2**20:.2f} MiB, "
              f"denials {m['admission_denials']}")
        if m["shards"] > 1:
            print(f"[serve] sharded: {m['shards']} shards, kv_sharded="
                  f"{m['kv_sharded']}, {m['kv_heads_per_shard']} KV "
                  f"head(s)/shard, peak KV/shard "
                  f"{m['peak_kv_bytes_per_shard'] / 2**20:.2f} MiB")
        if m["slab_bytes_per_seq"] or m["cross_bytes_per_entry"]:
            print(f"[serve] state cache: peak "
                  f"{m['peak_state_bytes'] / 2**20:.2f} MiB "
                  f"(slabs {m['peak_slabs']} x "
                  f"{m['slab_bytes_per_seq'] / 2**20:.2f} MiB, cross "
                  f"{m['peak_cross']} x "
                  f"{m['cross_bytes_per_entry'] / 2**20:.2f} MiB, "
                  f"{m['cross_hits']}/{m['cross_lookups']} cross hits)")
        if m["scheduler"] == "cb":
            host_cap = ("inf" if m["host_pages"] is None
                        else m["host_pages"])
            print(f"[serve] cb scheduler: {m['preemptions']} preemptions, "
                  f"{m['resumes']} resumes, "
                  f"{m['offload_bytes'] / 2**10:.1f} KiB offloaded, "
                  f"host tier peak {m['peak_host_pages']}/{host_cap} pages")
        if m["prefix_cache"]:
            print(f"[serve] prefix cache: {m['prefix_hits']} hits, "
                  f"{m['prefill_tokens_skipped']} prefill tokens skipped, "
                  f"{m['cow_copies']} COW copies, hit rate "
                  f"{m['prefix_hit_rate']:.2f}, "
                  f"{m['prefix_evictions']} evictions")
        if m["spec_decode"]:
            print(f"[serve] spec decode: K={m['spec_k']}, "
                  f"{m['model_calls']} model calls, "
                  f"{m['accepted_per_step']:.2f} accepted/step, "
                  f"acceptance {m['draft_acceptance_rate']:.2f}")
    print("[serve] metrics: " + json.dumps(m, sort_keys=True))
    return done


if __name__ == "__main__":
    main()
