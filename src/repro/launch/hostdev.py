"""Forced host device counts, handled in one place.

``--xla_force_host_platform_device_count=N`` makes the CPU backend expose
N fake devices — how every multi-device path here (dry-runs, sharded
serving tests, sharded benches) runs on one CPU. The flag only works if
it is in ``XLA_FLAGS`` *before* jax initializes its backend, which makes
it exactly the kind of global a module must not set at import time:
PR 6's ``launch/dryrun.py`` did, and every process that imported anything
from it inherited 512 fake devices (benchmarks/roofline.py grew a lazy
import to dodge that).

This module is the shared helper instead — import-safe (never touches
jax), explicit about process boundaries:

* ``set_host_device_count(n)`` — mutate THIS process's ``XLA_FLAGS``.
  Call it at the top of a ``main()``, before anything runs a jax
  computation. Replaces an existing force flag rather than stacking a
  second one; preserves unrelated flags.
* ``host_device_env(n)`` — a copy of the environment with the flag set,
  for spawning a subprocess with its own device count.
* ``run_with_host_devices(argv, n)`` — subprocess.run with that env
  (the pattern tests/test_sharded_serving.py and the sharded serving
  bench use: the parent process keeps its real device topology).
* ``forced_host_device_count()`` — parse the current flag, or None.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

__all__ = ["set_host_device_count", "host_device_env",
           "run_with_host_devices", "forced_host_device_count"]

_FLAG = "--xla_force_host_platform_device_count"
_FLAG_RE = re.compile(re.escape(_FLAG) + r"=(\d+)")


def forced_host_device_count(env=None) -> int | None:
    """The forced host device count in ``env`` (default: this process's
    environment), or None when the flag is absent."""
    env = os.environ if env is None else env
    m = _FLAG_RE.search(env.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else None


def _with_flag(flags: str, n: int) -> str:
    """``flags`` with the force flag set to ``n`` (replacing any existing
    occurrence, keeping every other flag)."""
    if _FLAG_RE.search(flags):
        return _FLAG_RE.sub(f"{_FLAG}={n}", flags)
    return f"{flags} {_FLAG}={n}".strip()


def set_host_device_count(n: int) -> None:
    """Force ``n`` host devices for THIS process. Only effective before
    jax initializes its backend — call it first thing in a ``main()``,
    never at module import."""
    if n < 1:
        raise ValueError(f"host device count must be >= 1, got {n}")
    os.environ["XLA_FLAGS"] = _with_flag(os.environ.get("XLA_FLAGS", ""), n)


def host_device_env(n: int, base=None) -> dict:
    """A copy of ``base`` (default: this environment) with the force flag
    set to ``n`` — for subprocesses that need their own device count."""
    if n < 1:
        raise ValueError(f"host device count must be >= 1, got {n}")
    env = dict(os.environ if base is None else base)
    env["XLA_FLAGS"] = _with_flag(env.get("XLA_FLAGS", ""), n)
    return env


def run_with_host_devices(argv, n: int, *, timeout=600, check=False,
                          **kw) -> subprocess.CompletedProcess:
    """Run ``argv`` (or a ``python -c`` source string) in a subprocess
    with ``n`` forced host devices. The child gets a fresh jax backend,
    so the flag actually applies; the parent's device topology is
    untouched — this is the ONLY safe way to mix device counts in one
    test/bench process tree."""
    if isinstance(argv, str):
        argv = [sys.executable, "-c", argv]
    env = host_device_env(n, base=kw.pop("env", None))
    kw.setdefault("capture_output", True)
    kw.setdefault("text", True)
    return subprocess.run(list(argv), env=env, timeout=timeout,
                          check=check, **kw)
