"""Production meshes. A function (not a module constant) so importing never
touches jax device state. Single pod: 16x16 = 256 chips (data x model).
Multi-pod: 2x16x16 = 512 chips; the 'pod' axis crosses DCN and is used only
for data parallelism (gradient all-reduce) — parameters never shard over it.
"""
from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; older versions infer Auto
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover
    AxisType = None

__all__ = ["make_production_mesh", "make_host_mesh", "make_serving_mesh",
           "mesh_axis_kwargs", "ambient_mesh"]


def ambient_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh where this
    jax supports one (jax.set_mesh / jax.sharding.use_mesh); no-op
    otherwise — explicit NamedShardings on jit in/out cover our use."""
    import contextlib
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext()


def mesh_axis_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where this jax supports it, else nothing."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         **mesh_axis_kwargs(2))


def make_serving_mesh(model: int = 1, devices=None):
    """``(data=1, model)`` mesh over an *explicit* device slice.

    Unlike ``make_host_mesh`` this never reaches for the global device
    list when a slice is given, so a replica router can pin each engine
    replica to its own disjoint devices. The data axis exists (size 1)
    because the forward passes' sharding constraints name both axes.
    """
    import numpy as np
    from jax.sharding import Mesh
    devs = list(devices) if devices is not None else jax.devices()[:model]
    if len(devs) != model:
        raise ValueError(
            f"make_serving_mesh(model={model}) needs exactly {model} "
            f"devices, got {len(devs)}")
    arr = np.empty((1, model), dtype=object)
    arr[0, :] = devs
    return Mesh(arr, ("data", "model"), **mesh_axis_kwargs(2))
