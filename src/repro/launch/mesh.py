"""Production meshes. A function (not a module constant) so importing never
touches jax device state. Single pod: 16x16 = 256 chips (data x model).
Multi-pod: 2x16x16 = 512 chips; the 'pod' axis crosses DCN and is used only
for data parallelism (gradient all-reduce) — parameters never shard over it.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
