"""SPx gradient compression with error feedback — the paper's quantizer
applied to the distributed-optimization layer.

Cross-pod (DCN) bandwidth is the scarcest link in a multi-pod job; the DP
gradient all-reduce is the only traffic that crosses it (DESIGN.md §4).
Compressing that reduction to 8-bit SPx codes cuts DCN bytes 4x (f32) /
2x (bf16). Error feedback keeps the scheme unbiased over time: the residual
(g - Q(g)) is added back into the next step's gradient, which provably
preserves SGD convergence for quantizers with bounded relative error.

Usage (inside a jit'd train step):
    comp = GradCompressor("sp2_8")
    ef = comp.init(grads)                     # error-feedback buffers
    grads_c, ef = comp.compress(grads, ef)    # quantize (+EF) pre-reduce
The compressed representation here is the fake-quantized tensor — XLA's
all-reduce then moves values that carry <=8 bits of information; on a real
DCN fabric the runtime ships the codes + scale. The EF state is what makes
the low-bit reduction semantically safe, and is what we test.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import spx

__all__ = ["GradCompressor"]


@dataclasses.dataclass(frozen=True)
class GradCompressor:
    scheme: str = "sp2_8"
    min_size: int = 4096        # don't bother compressing small leaves

    def _eligible(self, leaf) -> bool:
        return leaf.size >= self.min_size and jnp.issubdtype(
            leaf.dtype, jnp.floating)

    def init(self, grads):
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32)
            if self._eligible(g) else jnp.zeros((), jnp.float32), grads)

    def compress(self, grads, ef):
        """Returns (compressed grads, new error-feedback state)."""
        levels = spx.scheme_levels(self.scheme)
        lut = spx.codebook(levels)

        def one(g, e):
            if not self._eligible(g):
                return g, jnp.zeros((), jnp.float32)
            g32 = g.astype(jnp.float32) + e          # add back residual
            scale = jnp.max(jnp.abs(g32), axis=-1, keepdims=True)
            scale = jnp.maximum(scale, 1e-20)
            codes = spx.quantize_to_codes(g32, levels, scale)
            gq = spx.dequantize_codes(codes, lut, scale, dtype=jnp.float32)
            return gq.astype(g.dtype), g32 - gq      # new residual

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(ef)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        gq = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        new_ef = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        return gq, new_ef

    def wire_bits(self) -> int:
        return spx.code_width(spx.scheme_levels(self.scheme))
