"""Optimizers, implemented from scratch (no optax offline): SGD(+momentum),
AdamW, and AdamW-Q8 — AdamW with SPx-quantized (8-bit) moments. Q8 moments
halve→quarter optimizer HBM versus f32 Adam, which is what lets the 1T-param
config fit 512 v5e chips (DESIGN.md §4); it is also the paper's quantization
applied beyond inference.

API: opt = make_optimizer("adamw", lr=1e-3); state = opt.init(params);
params, state = opt.update(params, grads, state).
All updates are pure jit-able pytree maps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import spx
from repro.core.quantized import QuantizedTensor

__all__ = ["Optimizer", "make_optimizer", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (params, grads, state) ->
                                               # (params, state)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    # multiply in the grad's own dtype (bf16 grads stay bf16 — halves the
    # transient grad-tree bytes; the f32 accumulation happens in the moments)
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# SGD (+momentum) — the paper's §4.1 training rule (eta=0.5, plain SGD)
# ---------------------------------------------------------------------------

def _sgd(lr: float, momentum: float = 0.0):
    def init(params):
        step = jnp.zeros((), jnp.int32)
        if momentum == 0.0:
            return {"step": step}
        return {"step": step,
                "mu": jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(params, grads, state):
        if momentum == 0.0:
            new_p = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_p, {"step": state["step"] + 1}
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        new_p = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        return new_p, {"step": state["step"] + 1, "mu": mu}

    return Optimizer("sgd", init, update)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def _adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.0):
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(z, params),
                "nu": jax.tree_util.tree_map(z, params)}

    def update(params, grads, state):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, params, grads, state["mu"],
                                     state["nu"])
        new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda o: o[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda o: o[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"step": step, "mu": mu, "nu": nu}

    return Optimizer("adamw", init, update)


# ---------------------------------------------------------------------------
# AdamW-Q8: SPx 8-bit quantized moments (beyond-paper, on-theme)
# ---------------------------------------------------------------------------

_MOM_SCHEME = "sp2_8"        # signed, nonuniform — matches grad distribution
_VAR_SCHEME = "uniform8"     # nu >= 0; uniform on [0, max]


def _q8_state(p):
    """Per-leaf: codes uint8 + one f32 scale per last-dim channel."""
    shape = p.shape
    scale_shape = shape[:-1] + (1,) if len(shape) >= 1 else (1,)
    return {"codes": jnp.zeros(shape, jnp.uint8),
            "scale": jnp.zeros(scale_shape, jnp.float32)}


def _q8_read(q, levels_lut):
    return spx.dequantize_codes(q["codes"], levels_lut, q["scale"],
                                dtype=jnp.float32)


def _q8_write(x, levels, levels_lut):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) if x.ndim >= 1 \
        else jnp.abs(x)
    scale = jnp.maximum(scale, 1e-20)
    codes = spx.quantize_to_codes(x, levels, scale)
    return {"codes": codes, "scale": scale}


def _adamw_q8(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
              weight_decay: float = 0.0):
    m_levels = spx.scheme_levels(_MOM_SCHEME)
    m_lut = spx.codebook(m_levels)
    v_levels_np = spx.scheme_levels(_VAR_SCHEME)
    # variance is non-negative: use the non-negative half, rescaled
    import numpy as np
    v_levels = np.asarray(v_levels_np)
    v_levels = v_levels[v_levels >= 0]
    v_levels = v_levels / v_levels.max()
    v_lut = spx.codebook(v_levels)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree_util.tree_map(_q8_state, params),
                "nu": jax.tree_util.tree_map(_q8_state, params)}

    def update(params, grads, state):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd_slice(p, g, mq, vq):
            g = g.astype(jnp.float32)
            m = b1 * _q8_read(mq, m_lut) + (1 - b1) * g
            v = b2 * _q8_read(vq, v_lut) + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return new_p, _q8_write(m, m_levels, m_lut), \
                _q8_write(v, v_levels, v_lut)

        def upd(p, g, mq, vq):
            # large stacked leaves (layer-scanned params): update one
            # layer-slice at a time via lax.map — the f32 dequantized
            # moments exist only per slice, never for the whole (L, ...)
            # stack (61x smaller transients on the 1T MoE config)
            if p.ndim >= 3 and p.shape[0] > 1 and p.size > 2 ** 24:
                return jax.lax.map(lambda t: upd_slice(*t), (p, g, mq, vq))
            return upd_slice(p, g, mq, vq)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["mu"])
        flat_v = treedef.flatten_up_to(state["nu"])
        outs = [upd(p, g, m, v) for p, g, m, v in
                zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
        return new_p, {"step": step, "mu": mu, "nu": nu}

    return Optimizer("adamw_q8", init, update)


def make_optimizer(name: str, lr: float, **kw) -> Optimizer:
    if name == "sgd":
        return _sgd(lr, kw.get("momentum", 0.0))
    if name == "adamw":
        return _adamw(lr, **kw)
    if name == "adamw_q8":
        return _adamw_q8(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
