from .optimizer import Optimizer, clip_by_global_norm, global_norm, make_optimizer
from .checkpoint import (latest_step, list_checkpoints, restore_checkpoint,
                         save_checkpoint)
from .compression import GradCompressor
from .train_loop import (StallDetected, StepWatchdog, TrainConfig, TrainLoop,
                         make_grad_accum_step)
