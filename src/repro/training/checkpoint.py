"""Mesh-agnostic checkpointing with atomic commits and elastic restore.

Layout: <dir>/step_<N>/
  manifest.json          — step, leaf index (path -> file, shape, dtype), rng
  leaf_<i>.npy           — one file per pytree leaf, saved UNSHARDED
  _COMMITTED             — written last (atomic rename of tmpdir -> final)

Because leaves are stored logically unsharded, a checkpoint written on a
16x16 mesh restores onto 2x16x16 (or a single CPU device) untouched — this
is the elastic-rescale path: kill the job, change the mesh, resume.
numpy-only (no orbax offline), safe against partial writes.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_checkpoints"]

_COMMIT = "_COMMITTED"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    extra: dict | None = None, keep: int = 3) -> str:
    """Write atomically; prune to the newest ``keep`` checkpoints."""
    os.makedirs(ckpt_dir, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
    index = []
    try:
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(jax.device_get(leaf))
            dtype_str = str(arr.dtype)
            if arr.dtype.kind not in "fiub" or dtype_str == "bfloat16":
                # numpy can't round-trip extension dtypes (bfloat16, fp8)
                # through .npy — store as f32 (lossless widening), restore
                # casts back to the template dtype
                arr = arr.astype(np.float32)
            fname = f"leaf_{i}.npy"
            np.save(os.path.join(tmp, fname), arr)
            index.append({"path": p, "file": fname,
                          "shape": list(arr.shape), "dtype": dtype_str})
        manifest = {"step": step, "index": index, "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, _COMMIT), "w") as f:
            f.write("ok")
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(list_checkpoints(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def list_checkpoints(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, _COMMIT)):
            out.append(int(name.split("_", 1)[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, template: Any, *, step: int | None = None,
                       shardings: Any = None):
    """Restore into the structure of ``template`` (arrays or SDS). With
    ``shardings`` (a NamedSharding pytree) each leaf is device_put with its
    target sharding — this is where elastic re-scaling happens.
    Returns (tree, step, extra)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    paths, leaves, treedef = _flatten_with_paths(template)
    by_path = {e["path"]: e for e in manifest["index"]}
    if set(paths) != set(by_path):
        missing = set(paths) - set(by_path)
        extra_p = set(by_path) - set(paths)
        raise ValueError(f"checkpoint/template mismatch: missing={sorted(missing)[:4]} "
                         f"extra={sorted(extra_p)[:4]}")
    s_leaves = None
    if shardings is not None:
        s_flat, _ = jax.tree_util.tree_flatten(shardings)
        s_leaves = s_flat

    out = []
    for i, (p, tmpl) in enumerate(zip(paths, leaves)):
        arr = np.load(os.path.join(d, by_path[p]["file"]))
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch at {p}: ckpt {arr.shape} "
                             f"vs template {tmpl.shape}")
        if s_leaves is not None:
            out.append(jax.device_put(arr.astype(tmpl.dtype), s_leaves[i]))
        else:
            out.append(jnp.asarray(arr, dtype=tmpl.dtype))
    return (jax.tree_util.tree_unflatten(treedef, out), manifest["step"],
            manifest.get("extra", {}))
