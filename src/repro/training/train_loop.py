"""The training driver: step compilation, grad accumulation, periodic
checkpointing, preemption recovery, and a straggler/stall watchdog.

Fault model (DESIGN.md §4):
  * process death / preemption  -> restart resumes from the latest committed
    checkpoint (atomic commit protocol in checkpoint.py); `--kill-at-step`
    injects this in CI.
  * step stall / straggler      -> StepWatchdog tracks an EMA of step times;
    a step exceeding ``stall_factor`` x EMA raises StallDetected so the
    driver can checkpoint + re-enter (on real fleets: re-schedule the pod).
  * elastic rescale             -> checkpoints are mesh-agnostic; restore
    re-shards onto whatever mesh the restarted job has.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .compression import GradCompressor
from .optimizer import Optimizer, clip_by_global_norm

__all__ = ["TrainLoop", "StepWatchdog", "StallDetected", "TrainConfig",
           "make_grad_accum_step"]


class StallDetected(RuntimeError):
    pass


class StepWatchdog:
    """EMA step-time tracker; flags stragglers/stalls."""

    def __init__(self, stall_factor: float = 5.0, warmup: int = 3,
                 min_stall_s: float = 1.0):
        self.stall_factor = stall_factor
        self.warmup = warmup
        self.min_stall_s = min_stall_s
        self.ema = None
        self.n = 0
        self.stalls = 0

    def observe(self, dt: float):
        self.n += 1
        if self.n <= self.warmup:
            self.ema = dt if self.ema is None else 0.5 * (self.ema + dt)
            return
        threshold = max(self.stall_factor * self.ema, self.min_stall_s)
        if dt > threshold:
            self.stalls += 1
            raise StallDetected(
                f"step took {dt:.2f}s vs EMA {self.ema:.2f}s "
                f"(factor {self.stall_factor})")
        self.ema = 0.9 * self.ema + 0.1 * dt


@dataclasses.dataclass
class TrainConfig:
    max_steps: int = 100
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    grad_clip: float = 1.0
    accum_steps: int = 1
    compress_grads: str | None = None   # e.g. "sp2_8" for cross-pod DP
    kill_at_step: int | None = None     # fault injection (CI)


def make_grad_accum_step(loss_fn: Callable, opt: Optimizer, *,
                         accum_steps: int = 1, grad_clip: float = 1.0,
                         compressor: GradCompressor | None = None,
                         pod_axis: str | None = None, rt=None):
    """Build a jit-able step: (params, opt_state, ef, batch) ->
    (params, opt_state, ef, metrics).

    With accum_steps > 1 the batch's leading dim is split into microbatches
    and scanned — the backward of microbatch i overlaps XLA's DP reduce of
    microbatch i-1 (latency-hiding scheduler).
    With a compressor, gradients are SPx-fake-quantized with error feedback
    before the (cross-pod) mean — see compression.py.
    With ``rt`` (a frozen repro.runtime.Runtime), ``loss_fn`` is called as
    ``loss_fn(params, batch, rt)`` — the Runtime binds here, once, instead
    of being closed over ad hoc at every driver callsite.
    """
    if rt is not None:
        inner_loss = loss_fn
        loss_fn = lambda params, batch: inner_loss(params, batch, rt)
    def step(params, opt_state, ef, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, (l, m)

            micro_batches = jax.tree_util.tree_map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricses) = jax.lax.scan(micro, zero,
                                                      micro_batches)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree_util.tree_map(jnp.mean, metricses)

        if compressor is not None:
            grads, ef = compressor.compress(grads, ef)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = opt.update(params, grads, opt_state)
        metrics = dict(metrics, loss=loss, gnorm=gnorm)
        return params, opt_state, ef, metrics

    return step


class TrainLoop:
    """Drives steps with checkpoint/restart + watchdog. Generic over model:
    needs loss_fn(params, batch), an Optimizer, an init params fn and a data
    iterator."""

    def __init__(self, loss_fn, opt: Optimizer, init_params_fn,
                 data_iter, cfg: TrainConfig, *,
                 compressor: GradCompressor | None = None,
                 donate: bool = True, rt=None):
        self.cfg = cfg
        self.opt = opt
        self.loss_fn = loss_fn
        self.init_params_fn = init_params_fn
        self.data = data_iter
        self.compressor = compressor
        self.rt = rt
        step = make_grad_accum_step(
            loss_fn, opt, accum_steps=cfg.accum_steps,
            grad_clip=cfg.grad_clip, compressor=compressor, rt=rt)
        self._step = jax.jit(step, donate_argnums=(0, 1, 2) if donate else ())
        self.watchdog = StepWatchdog()
        self.history: list[dict] = []

    # -- state bootstrap ----------------------------------------------------

    def init_or_restore(self):
        params = self.init_params_fn()
        opt_state = self.opt.init(params)
        ef = (self.compressor.init(params) if self.compressor
              else jnp.zeros(()))
        start = 0
        if self.cfg.ckpt_dir and latest_step(self.cfg.ckpt_dir) is not None:
            (params, opt_state, ef), start, _ = restore_checkpoint(
                self.cfg.ckpt_dir, (params, opt_state, ef))
            print(f"[train] resumed from step {start}")
        return params, opt_state, ef, start

    # -- main loop ------------------------------------------------------------

    def run(self):
        params, opt_state, ef, start = self.init_or_restore()
        step_i = start
        while step_i < self.cfg.max_steps:
            batch = next(self.data)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.monotonic()
            if (self.cfg.kill_at_step is not None
                    and step_i == self.cfg.kill_at_step):
                raise KeyboardInterrupt(
                    f"fault injection: killed at step {step_i}")
            params, opt_state, ef, metrics = self._step(params, opt_state,
                                                        ef, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            step_i += 1
            try:
                self.watchdog.observe(dt)
            except StallDetected as e:
                print(f"[watchdog] {e}; checkpointing and continuing")
                if self.cfg.ckpt_dir:
                    save_checkpoint(self.cfg.ckpt_dir, step_i,
                                    (params, opt_state, ef),
                                    keep=self.cfg.keep_ckpts)
            rec = {"step": step_i,
                   "loss": float(metrics["loss"]),
                   "dt": dt}
            self.history.append(rec)
            if step_i % self.cfg.log_every == 0:
                print(f"[train] step {step_i} loss {rec['loss']:.4f} "
                      f"({dt*1e3:.0f} ms)")
            if (self.cfg.ckpt_dir and self.cfg.ckpt_every
                    and step_i % self.cfg.ckpt_every == 0):
                save_checkpoint(self.cfg.ckpt_dir, step_i,
                                (params, opt_state, ef),
                                keep=self.cfg.keep_ckpts)
        if self.cfg.ckpt_dir:
            save_checkpoint(self.cfg.ckpt_dir, step_i,
                            (params, opt_state, ef),
                            keep=self.cfg.keep_ckpts)
        return params, self.history
