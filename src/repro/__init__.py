"""repro: production-grade JAX/TPU reproduction of 'A Deep Learning
Inference Scheme Based on Pipelined Matrix Multiplication Acceleration
Design and Non-uniform Quantization' (Zhang, Leung et al., 2021)."""
__version__ = "1.0.0"
