"""Encoder-decoder backbone (Whisper-small assignment). The audio conv
frontend is a stub per the assignment: inputs are precomputed frame
embeddings (B, enc_seq_len, D). The decoder is an ``xdec+dense`` stack with
per-layer cross-attention KV cached at prefill."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.layers import (dense_init, embedding_apply, embedding_init,
                             norm_apply, norm_init)
from repro.runtime import Runtime
from repro.nn.transformer import (slot_init_cache, stack_apply, stack_decode,
                                  stack_init, stack_prefill)
from .lm import _default_positions, _head_w, chunked_ce

__all__ = ["encdec_init", "encdec_loss", "encdec_encode", "encdec_prefill",
           "encdec_decode_step", "encdec_init_caches", "enc_cfg", "dec_cfg"]


def enc_cfg(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, pattern=("attn+dense",),
                               n_layers=cfg.n_enc_layers)


def dec_cfg(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, pattern=("xdec+dense",))


def encdec_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model,
                                dtype=dtype),
        "enc_stack": stack_init(ks[1], enc_cfg(cfg), dtype=dtype),
        "enc_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        "dec_stack": stack_init(ks[2], dec_cfg(cfg), dtype=dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        "head": dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def encdec_encode(params, frames: jax.Array, cfg: ArchConfig, rt: Runtime):
    """frames: (B, S_enc, D) stub embeddings -> encoder output."""
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, _ = stack_apply(params["enc_stack"], frames, pos, enc_cfg(cfg), rt,
                       causal=False)
    return norm_apply(cfg.norm, params["enc_norm"], h)


def encdec_loss(params, batch: dict, cfg: ArchConfig, rt: Runtime):
    """batch: {'frames': (B,S_enc,D), 'tokens': (B,S), 'labels': (B,S)}."""
    enc_out = encdec_encode(params, batch["frames"], cfg, rt)
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = _default_positions(cfg, b, s)
    x = embedding_apply(params["embed"], tokens)
    h, aux = stack_apply(params["dec_stack"], x, pos, dec_cfg(cfg), rt,
                         enc_out=enc_out)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    ce, z = chunked_ce(h, params["head"]["w"], batch["labels"], rt=rt,
                       unroll=rt.unroll)
    return ce + 1e-4 * z, {"ce": ce, "z": z}


def encdec_init_caches(cfg: ArchConfig, batch: int, max_seq: int,
                       dtype=jnp.bfloat16, kv_quant: bool = False):
    dcfg = dec_cfg(cfg)
    return [slot_init_cache(slot, dcfg, batch, max_seq, dtype,
                            kv_quant=kv_quant)
            for slot in dcfg.pattern]


def encdec_prefill(params, frames, tokens, caches, cfg: ArchConfig,
                   rt: Runtime):
    """Encode + run decoder prompt, filling self- and cross-attn caches."""
    enc_out = encdec_encode(params, frames, cfg, rt)
    b, s = tokens.shape
    pos = _default_positions(cfg, b, s)
    x = embedding_apply(params["embed"], tokens)
    dcfg = dec_cfg(cfg)
    h, new_caches, _ = stack_prefill(params["dec_stack"], x, pos, dcfg, rt,
                                     caches, enc_out=enc_out)
    h = norm_apply(cfg.norm, params["final_norm"], h[:, -1:])
    logits = jnp.dot(h[:, 0], params["head"]["w"].astype(h.dtype))
    return logits, new_caches


def encdec_decode_step(params, token, pos, caches, cfg: ArchConfig,
                       rt: Runtime):
    x = embedding_apply(params["embed"], token[:, None])
    dcfg = dec_cfg(cfg)
    h, new_caches = stack_decode(params["dec_stack"], x, pos, dcfg, rt,
                                 caches)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    logits = jnp.dot(h[:, 0], params["head"]["w"].astype(h.dtype))
    return logits, new_caches
