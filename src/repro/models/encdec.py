"""Encoder-decoder backbone (Whisper-small assignment). The audio conv
frontend is a stub per the assignment: inputs are precomputed frame
embeddings (B, enc_seq_len, D). The decoder is an ``xdec+dense`` stack with
per-layer cross-attention KV cached at prefill."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.layers import (dense_init, embedding_apply, embedding_init,
                             norm_apply, norm_init)
from repro.runtime import Runtime
from repro.nn.transformer import (_cross_kv, slot_init_cache,
                                  slot_init_paged_cache, stack_apply,
                                  stack_decode, stack_init, stack_paged,
                                  stack_prefill)
from .lm import _default_positions, _head_w, chunked_ce

__all__ = ["encdec_init", "encdec_loss", "encdec_encode", "encdec_prefill",
           "encdec_decode_step", "encdec_init_caches", "enc_cfg", "dec_cfg",
           "encdec_paged_init_caches", "encdec_cross_kv",
           "encdec_paged_step", "encdec_paged_verify",
           "encdec_paged_fused_step"]


def enc_cfg(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, pattern=("attn+dense",),
                               n_layers=cfg.n_enc_layers)


def dec_cfg(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, pattern=("xdec+dense",))


def encdec_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model,
                                dtype=dtype),
        "enc_stack": stack_init(ks[1], enc_cfg(cfg), dtype=dtype),
        "enc_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        "dec_stack": stack_init(ks[2], dec_cfg(cfg), dtype=dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        "head": dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def encdec_encode(params, frames: jax.Array, cfg: ArchConfig, rt: Runtime):
    """frames: (B, S_enc, D) stub embeddings -> encoder output."""
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, _ = stack_apply(params["enc_stack"], frames, pos, enc_cfg(cfg), rt,
                       causal=False)
    return norm_apply(cfg.norm, params["enc_norm"], h)


def encdec_loss(params, batch: dict, cfg: ArchConfig, rt: Runtime):
    """batch: {'frames': (B,S_enc,D), 'tokens': (B,S), 'labels': (B,S)}."""
    enc_out = encdec_encode(params, batch["frames"], cfg, rt)
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = _default_positions(cfg, b, s)
    x = embedding_apply(params["embed"], tokens)
    h, aux = stack_apply(params["dec_stack"], x, pos, dec_cfg(cfg), rt,
                         enc_out=enc_out)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    ce, z = chunked_ce(h, params["head"]["w"], batch["labels"], rt=rt,
                       unroll=rt.unroll)
    return ce + 1e-4 * z, {"ce": ce, "z": z}


def encdec_init_caches(cfg: ArchConfig, batch: int, max_seq: int,
                       dtype=jnp.bfloat16, kv_quant: bool = False):
    dcfg = dec_cfg(cfg)
    return [slot_init_cache(slot, dcfg, batch, max_seq, dtype,
                            kv_quant=kv_quant)
            for slot in dcfg.pattern]


def encdec_prefill(params, frames, tokens, caches, cfg: ArchConfig,
                   rt: Runtime):
    """Encode + run decoder prompt, filling self- and cross-attn caches."""
    enc_out = encdec_encode(params, frames, cfg, rt)
    b, s = tokens.shape
    pos = _default_positions(cfg, b, s)
    x = embedding_apply(params["embed"], tokens)
    dcfg = dec_cfg(cfg)
    h, new_caches, _ = stack_prefill(params["dec_stack"], x, pos, dcfg, rt,
                                     caches, enc_out=enc_out)
    h = norm_apply(cfg.norm, params["final_norm"], h[:, -1:])
    logits = jnp.dot(h[:, 0], params["head"]["w"].astype(h.dtype))
    return logits, new_caches


def encdec_decode_step(params, token, pos, caches, cfg: ArchConfig,
                       rt: Runtime):
    x = embedding_apply(params["embed"], token[:, None])
    dcfg = dec_cfg(cfg)
    h, new_caches = stack_decode(params["dec_stack"], x, pos, dcfg, rt,
                                 caches)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    logits = jnp.dot(h[:, 0], params["head"]["w"].astype(h.dtype))
    return logits, new_caches


# -- paged serving (unified state-cache) -------------------------------------

def encdec_paged_init_caches(cfg: ArchConfig, n_pages: int, page_size: int,
                             dtype=jnp.bfloat16, kv_quant: bool = False,
                             n_slabs: int = 0, n_cross: int = 0):
    """Decoder state-cache regions: token-paged self-attention KV pools
    plus ``n_cross`` read-only encoder-output entries per xdec slot (the
    encoder itself holds no serving state — its output is projected once
    per distinct input via ``encdec_cross_kv`` and shared)."""
    dcfg = dec_cfg(cfg)
    return [slot_init_paged_cache(slot, dcfg, n_pages, page_size, dtype,
                                  kv_quant=kv_quant, n_slabs=n_slabs,
                                  n_cross=n_cross)
            for slot in dcfg.pattern]


def encdec_cross_kv(params, frames: jax.Array, cfg: ArchConfig,
                    rt: Runtime):
    """Run the encoder once and project its output through every decoder
    slot x period's cross-attention K/V: frames (B, S_enc, D) -> per-slot
    list of ``None`` (non-xdec slots) or {"xk", "xv"} arrays shaped
    (P, B, Hkv, S_enc, dh) — exactly what ``lm.paged_fill_cross`` writes
    into a cross entry (B = 1 there: one entry per distinct input). The
    per-period projection weights are stacked on axis 0, so a vmap over
    the slot params applies all periods in one call (QuantizedTensor is a
    registered pytree — vmap slices its codes like any array)."""
    enc_out = encdec_encode(params, frames, cfg, rt)
    dcfg = dec_cfg(cfg)
    out = []
    for j, slot in enumerate(dcfg.pattern):
        if slot.split("+")[0] != "xdec":
            out.append(None)
            continue
        slot_params = params["dec_stack"]["slots"][j]

        def per_period(p_x):
            k, v = _cross_kv(p_x, enc_out, dcfg.n_kv_heads, dcfg.dh, rt)
            # (B, S_enc, Hkv, dh) -> (B, Hkv, S_enc, dh), the cache layout
            return (jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2))

        xk, xv = jax.vmap(per_period)(slot_params["xattn"])
        out.append({"xk": xk, "xv": xv})
    return out


def encdec_paged_step(params, tokens, ctx_len, block_table, n_valid,
                      state_idx, caches, cfg: ArchConfig, rt: Runtime):
    """Decoder twin of ``lm.lm_paged_step`` — same contract, decoder
    pattern, cross-attention reading the shared cross region via
    ``state_idx[:, 1]``. Returns (logits (B, V) at each row's last valid
    position, new_caches)."""
    x = embedding_apply(params["embed"], tokens)
    dcfg = dec_cfg(cfg)
    h, new_caches = stack_paged(params["dec_stack"], x, ctx_len,
                                block_table, n_valid, state_idx, dcfg, rt,
                                caches)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    last = jnp.clip(n_valid - 1, 0, tokens.shape[1] - 1)          # (B,)
    h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
    logits = jnp.dot(h_last, params["head"]["w"].astype(h.dtype))
    return logits, new_caches


def encdec_paged_verify(params, tokens, ctx_len, block_table, n_valid,
                        state_idx, caches, cfg: ArchConfig, rt: Runtime):
    """Decoder twin of ``lm.lm_paged_verify``: logits at every window
    position, (B, C, V)."""
    x = embedding_apply(params["embed"], tokens)
    dcfg = dec_cfg(cfg)
    h, new_caches = stack_paged(params["dec_stack"], x, ctx_len,
                                block_table, n_valid, state_idx, dcfg, rt,
                                caches)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    logits = jnp.dot(h, params["head"]["w"].astype(h.dtype))
    return logits, new_caches


def encdec_paged_fused_step(params, tokens, ctx_len, block_table, n_valid,
                            state_idx, caches, cfg: ArchConfig,
                            rt: Runtime):
    """Decoder twin of ``lm.lm_paged_fused_step``: the self-attention
    rides the ragged decode megakernel; cross-attention stays on the
    gather path (its KV is a dense per-entry block, not pages)."""
    x = embedding_apply(params["embed"], tokens)
    dcfg = dec_cfg(cfg)
    h, new_caches = stack_paged(params["dec_stack"], x, ctx_len,
                                block_table, n_valid, state_idx, dcfg, rt,
                                caches, fused=True)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    logits = jnp.dot(h, params["head"]["w"].astype(h.dtype))
    return logits, new_caches
