"""The paper's own model (§4.1): a 3-layer MLP, 784-128-10, sigmoid
activations, MSE loss against one-hot targets, trained with plain SGD
(B=64, eta=0.5). Faithful reproduction — the generic ``mlp_net`` variant is
also the Q-function approximator for the §4.2 RL experiment.

Inference can run through the dense path or the SPx-quantized pipelined
path (quantize_params + kernels.ops.spx_matmul) — the comparison between
them is the paper's Table-1/quantization experiment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.layers import dense_apply, dense_init
from repro.runtime import Runtime

__all__ = ["PAPER_LAYERS", "mlp_net_init", "mlp_net_apply", "paper_mlp_init",
           "paper_mlp_apply", "paper_mlp_loss", "paper_mlp_predict"]

PAPER_LAYERS = (784, 128, 10)


def mlp_net_init(key, sizes, *, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, len(sizes) - 1)
    return {f"l{i}": dense_init(ks[i], sizes[i], sizes[i + 1], bias=True,
                                dtype=dtype)
            for i in range(len(sizes) - 1)}


def mlp_net_apply(params: dict, x: jax.Array, *, act=jax.nn.sigmoid,
                  final_act=None, rt: Runtime | None = None) -> jax.Array:
    n = len(params)
    for i in range(n):
        x = dense_apply(params[f"l{i}"], x, rt)
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def paper_mlp_init(key, dtype=jnp.float32) -> dict:
    return mlp_net_init(key, PAPER_LAYERS, dtype=dtype)


def paper_mlp_apply(params: dict, x: jax.Array,
                    rt: Runtime | None = None) -> jax.Array:
    """Eq. 4.2: F(x) = sigmoid(W3 sigmoid(W2 x + b2) + b3). x: (B, 784)."""
    return mlp_net_apply(params, x, act=jax.nn.sigmoid,
                         final_act=jax.nn.sigmoid, rt=rt)


def paper_mlp_loss(params: dict, x: jax.Array, y: jax.Array,
                   rt: Runtime | None = None) -> jax.Array:
    """Eq. 4.5: mean squared error against one-hot labels."""
    out = paper_mlp_apply(params, x, rt)
    onehot = jax.nn.one_hot(y, 10, dtype=out.dtype)
    return jnp.mean(jnp.sum((out - onehot) ** 2, axis=-1))


def paper_mlp_predict(params: dict, x: jax.Array,
                      rt: Runtime | None = None) -> jax.Array:
    """Eq. 4.3: argmax over the 10 output components."""
    return jnp.argmax(paper_mlp_apply(params, x, rt), axis=-1)
