from .lm import (init_caches, lm_decode_step, lm_init, lm_logits, lm_loss,
                 lm_prefill)
from .encdec import (encdec_decode_step, encdec_encode, encdec_init,
                     encdec_init_caches, encdec_loss, encdec_prefill)
from .mlp_mnist import (paper_mlp_apply, paper_mlp_init, paper_mlp_loss,
                        paper_mlp_predict, mlp_net_apply, mlp_net_init)
