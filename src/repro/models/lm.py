"""Decoder-only language model: init / train loss / prefill / decode.

Covers dense (granite, gemma, qwen2.5, minitron), MoE (kimi-k2, olmoe),
SSM (xlstm), hybrid (jamba) and VLM-backbone (qwen2-vl, M-RoPE) families —
everything except enc-dec (see encdec.py). The vocabulary head is evaluated
in sequence chunks (never materializing (B, S, V)); the head weight shards
over the model axis when the vocab divides it.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.nn.layers import (dense_apply, dense_init, embedding_apply,
                             embedding_init, norm_apply, norm_init)
from repro.runtime import Runtime
from repro.nn.transformer import (slot_init_cache, slot_init_paged_cache,
                                  stack_apply, stack_decode, stack_paged,
                                  stack_prefill, stack_init)

__all__ = ["lm_init", "lm_loss", "lm_logits", "lm_prefill", "lm_decode_step",
           "init_caches", "paged_init_caches", "lm_paged_step",
           "lm_paged_verify", "lm_paged_fused_step", "paged_copy_page",
           "paged_gather_pages", "paged_scatter_pages",
           "paged_gather_slabs", "paged_scatter_slabs", "paged_reset_slabs",
           "paged_fill_cross", "chunked_ce"]

LOSS_CHUNK = 256
AUX_WEIGHT = 0.01
Z_WEIGHT = 1e-4


def lm_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "embed": embedding_init(ks[0], cfg.vocab_size, cfg.d_model,
                                dtype=dtype),
        "stack": stack_init(ks[1], cfg, dtype=dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size,
                               dtype=dtype)
    return p


def _head_w(params: dict, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T          # (D, V)
    return params["head"]["w"]


def _default_positions(cfg: ArchConfig, b: int, s: int):
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(pos[:, None, :], (b, 3, s))
    return pos


def chunked_ce(h: jax.Array, w_head: jax.Array, labels: jax.Array, *,
               chunk: int = LOSS_CHUNK, rt: Runtime | None = None,
               unroll: bool = False):
    """Mean token cross-entropy, scanning over sequence chunks so the
    (B, chunk, V) logits block is the only vocab-sized live tensor.
    Also returns z-loss (log^2 Z) for stability."""
    b, s, d = h.shape
    v = w_head.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    n_chunks = s // chunk
    w32 = w_head.astype(jnp.bfloat16)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, i):
        # checkpointed: the (B, chunk, V) logits block is recomputed in the
        # backward instead of being saved once per chunk (one cheap matmul)
        ce_sum, z_sum = carry
        h_i = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
        y_i = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        logits = jnp.dot(h_i.astype(jnp.bfloat16), w32,
                         preferred_element_type=jnp.float32)
        if rt is not None and rt.mesh is not None \
                and rt.model_axis is not None \
                and v % rt.mesh.shape[rt.model_axis] == 0:
            from jax.sharding import NamedSharding
            dp = rt.data_axes if rt.data_axes else None
            logits = jax.lax.with_sharding_constraint(
                logits, NamedSharding(rt.mesh, P(dp, None, rt.model_axis)))
        lse = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, y_i[..., None],
                                   axis=-1)[..., 0]
        ce_sum = ce_sum + jnp.sum(lse - true)
        z_sum = z_sum + jnp.sum(lse * lse)
        return (ce_sum, z_sum), None

    if n_chunks == 1:
        (ce, z), _ = body((jnp.zeros((), jnp.float32),
                           jnp.zeros((), jnp.float32)), 0)
    else:
        (ce, z), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(n_chunks), unroll=True if unroll else 1)
    n_tok = b * s
    return ce / n_tok, z / n_tok


def _backbone(params, cfg: ArchConfig, tokens, positions, rt: Runtime,
              embeds=None):
    x = embeds if embeds is not None else embedding_apply(params["embed"],
                                                          tokens)
    # sequence-sharded from the embedding on (SP/CP); batch over data axes
    from repro.nn.transformer import _sp_constrain
    x = _sp_constrain(x, rt)
    h, aux = stack_apply(params["stack"], x, positions, cfg, rt)
    return norm_apply(cfg.norm, params["final_norm"], h), aux


def lm_loss(params, batch: dict, cfg: ArchConfig, rt: Runtime):
    """batch: {'tokens': (B,S) int32, 'labels': (B,S) int32,
    optional 'positions'}. Returns (scalar loss, metrics dict)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, b, s)
    h, aux = _backbone(params, cfg, tokens, positions, rt,
                       embeds=batch.get("embeds"))
    ce, z = chunked_ce(h, _head_w(params, cfg), batch["labels"], rt=rt,
                       unroll=rt.unroll)
    loss = ce + AUX_WEIGHT * aux + Z_WEIGHT * z
    return loss, {"ce": ce, "aux": aux, "z": z}


def lm_logits(params, tokens, cfg: ArchConfig, rt: Runtime, positions=None,
              embeds=None):
    """Full-sequence logits (small-model/test use only)."""
    b, s = tokens.shape
    if positions is None:
        positions = _default_positions(cfg, b, s)
    h, _ = _backbone(params, cfg, tokens, positions, rt, embeds=embeds)
    return jnp.dot(h, _head_w(params, cfg).astype(h.dtype))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16, kv_quant: bool = False):
    return [slot_init_cache(slot, cfg, batch, max_seq, dtype,
                            kv_quant=kv_quant)
            for slot in cfg.pattern]


def lm_prefill(params, tokens, caches, cfg: ArchConfig, rt: Runtime,
               positions=None, embeds=None):
    """Run the prompt through the stack, fill caches, return last-position
    logits and the caches."""
    b, s = tokens.shape[:2]
    if positions is None:
        positions = _default_positions(cfg, b, s)
    x = embeds if embeds is not None else embedding_apply(params["embed"],
                                                          tokens)
    from repro.nn.transformer import _sp_constrain
    x = _sp_constrain(x, rt)
    h, new_caches, _ = stack_prefill(params["stack"], x, positions, cfg, rt,
                                     caches)
    h = norm_apply(cfg.norm, params["final_norm"], h[:, -1:])
    logits = jnp.dot(h[:, 0], _head_w(params, cfg).astype(h.dtype))
    return logits, new_caches


def lm_decode_step(params, token, pos, caches, cfg: ArchConfig, rt: Runtime):
    """One decode step. token: (B,) int32; pos: () int32 (current write
    position = number of tokens already in cache). Returns (logits (B, V),
    new_caches)."""
    x = embedding_apply(params["embed"], token[:, None])
    h, new_caches = stack_decode(params["stack"], x, pos, cfg, rt, caches)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    logits = jnp.dot(h[:, 0], _head_w(params, cfg).astype(h.dtype))
    return logits, new_caches


# -- paged serving (docs/SERVING.md) ----------------------------------------

def paged_init_caches(cfg: ArchConfig, n_pages: int, page_size: int,
                      dtype=jnp.bfloat16, kv_quant: bool = False,
                      n_slabs: int = 0, n_cross: int = 0):
    """Device state-cache regions for every slot in the pattern: KV page
    pools for attention slots, ``n_slabs`` recurrent-state slabs for SSM
    slots, ``n_cross`` read-only encoder-output entries for xdec slots —
    heterogeneous (hybrid) patterns get exactly the regions each slot
    needs. ``kv_quant`` switches the page pools to the codes+scale
    quantized layout (scheme from ``Runtime.kv_scheme`` at step time)."""
    return [slot_init_paged_cache(slot, cfg, n_pages, page_size, dtype,
                                  kv_quant=kv_quant, n_slabs=n_slabs,
                                  n_cross=n_cross)
            for slot in cfg.pattern]


# region partitioning by leaf key: the page pools ("kp"/"vp" — arrays or
# codes+scale dicts), the read-only cross entries ("xk"/"xv"), and
# everything else is per-sequence slab state (SSM h/conv/C/n/m/c leaves)
_PAGE_KEYS = ("kp", "vp")
_CROSS_KEYS = ("xk", "xv")


def _slab_keys(slot_cache: dict):
    return [k for k in slot_cache
            if k not in _PAGE_KEYS and k not in _CROSS_KEYS]


def paged_copy_page(caches, src, dst):
    """Copy one physical KV page ``src`` -> ``dst`` across every layer,
    period and head (K and V — and codes+scale pairs when the pool is
    quantized). This is the serving engine's copy-on-write: a request
    whose prompt fully matches a shared page up to its last token gets a
    private copy to finish (and later decode into) so the shared original
    stays immutable. Page index is axis 1 of every page-region leaf
    (``(P, n_pages, Hkv, page_size, dh)``); ``src``/``dst`` may be traced
    scalars, so one jit of this function serves every (src, dst) pair.
    Slab and cross regions pass through untouched — pages are the only
    copy-on-write region (slabs are exclusive, cross entries immutable).
    """
    def cp(leaf):
        return leaf.at[:, dst].set(leaf[:, src])
    out = []
    for slot_cache in caches:
        new = dict(slot_cache)
        for k in _PAGE_KEYS:
            if k in slot_cache:
                new[k] = jax.tree_util.tree_map(cp, slot_cache[k])
        out.append(new)
    return out


def paged_gather_pages(caches, pages):
    """Gather whole physical KV pages across every page-region leaf: the
    serving engine's preemption snapshot. ``pages`` is a (n,) int32 page
    index vector; each ``(P, n_pages, Hkv, page_size, dh)`` leaf yields
    ``(P, n, Hkv, page_size, dh)``. The index vector is traced, so one
    jit per padded length serves every page set of that size (the engine
    pads to powers of two, duplicating the last page — callers slice the
    duplicates off host-side). Returns the page-region subtree only (one
    dict per slot; empty for slab-only slots) — slab state snapshots
    through ``paged_gather_slabs``."""
    return [{k: jax.tree_util.tree_map(lambda leaf: leaf[:, pages],
                                       slot_cache[k])
             for k in _PAGE_KEYS if k in slot_cache}
            for slot_cache in caches]


def paged_scatter_pages(caches, pages, payload):
    """Scatter snapshotted pages back into the pool: the inverse of
    ``paged_gather_pages``, used when a preempted sequence resumes into
    freshly allocated pages. Duplicate indices in ``pages`` (the engine's
    pow2 padding) carry identical payload rows, so the write is
    deterministic regardless of scatter order."""
    out = []
    for slot_cache, pay in zip(caches, payload):
        new = dict(slot_cache)
        for k in _PAGE_KEYS:
            if k in slot_cache:
                new[k] = jax.tree_util.tree_map(
                    lambda leaf, p: leaf.at[:, pages].set(p),
                    slot_cache[k], pay[k])
        out.append(new)
    return out


def paged_gather_slabs(caches, slab):
    """Snapshot one slab's recurrent state across every SSM slot: each
    ``(P, n_slabs, ...)`` slab leaf yields ``(P, ...)``. ``slab`` may be
    a traced scalar — one jit serves every slab index. Returns the
    slab-region subtree only (one dict per slot; empty for attention
    slots)."""
    return [{k: slot_cache[k][:, slab] for k in _slab_keys(slot_cache)}
            for slot_cache in caches]


def paged_scatter_slabs(caches, slab, payload):
    """Restore a snapshotted slab (inverse of ``paged_gather_slabs``) —
    the resumed sequence may land on a different slab index than it was
    preempted from; the pool's ``seq_slab`` says where."""
    out = []
    for slot_cache, pay in zip(caches, payload):
        new = dict(slot_cache)
        for k in _slab_keys(slot_cache):
            new[k] = slot_cache[k].at[:, slab].set(
                pay[k].astype(slot_cache[k].dtype))
        out.append(new)
    return out


def paged_reset_slabs(caches, slab):
    """Zero one slab across every SSM slot — a freshly admitted sequence
    must start from the zero recurrent state, and its slab still holds
    whatever the previous owner left behind (pages don't need this: every
    page position is written before it is attended)."""
    out = []
    for slot_cache in caches:
        new = dict(slot_cache)
        for k in _slab_keys(slot_cache):
            leaf = slot_cache[k]
            new[k] = leaf.at[:, slab].set(
                jnp.zeros(leaf.shape[:1] + leaf.shape[2:], leaf.dtype))
        out.append(new)
    return out


def paged_fill_cross(caches, idx, entries):
    """Write one encoder pass's projected K/V into cross entry ``idx``
    across every xdec slot. ``entries``: per-slot ``None`` (non-xdec) or
    {"xk", "xv"} arrays shaped (P, 1, Hkv, enc_seq_len, dh) — the output
    of ``models.encdec.encdec_cross_kv`` on a single input. Entries are
    written once here and only ever read by the decode path (read-only
    sharing across sequences)."""
    out = []
    for slot_cache, ent in zip(caches, entries):
        new = dict(slot_cache)
        if ent is not None:
            for k in _CROSS_KEYS:
                new[k] = slot_cache[k].at[:, idx].set(
                    ent[k][:, 0].astype(slot_cache[k].dtype))
        out.append(new)
    return out


def lm_paged_step(params, tokens, ctx_len, block_table, n_valid, state_idx,
                  caches, cfg: ArchConfig, rt: Runtime):
    """One paged engine step: run the next C tokens of each sequence —
    a prefill chunk (C > 1) or a decode step (C == 1) — against the
    unified state-cache.

    tokens: (B, C) int32 (rows may be padded past ``n_valid``);
    ctx_len: (B,) int32 tokens already in the pages; block_table:
    (B, max_pages) int32; n_valid: (B,) int32 valid tokens in this chunk
    (0 = inactive row); state_idx: (B, 2) int32 per-row (slab, cross)
    indices, out-of-range sentinels for rows without that region (pure
    attention patterns pass all-sentinel). Returns (logits (B, V) at each
    row's last valid position, new_caches).
    """
    x = embedding_apply(params["embed"], tokens)
    h, new_caches = stack_paged(params["stack"], x, ctx_len, block_table,
                                n_valid, state_idx, cfg, rt, caches)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    last = jnp.clip(n_valid - 1, 0, tokens.shape[1] - 1)          # (B,)
    h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
    logits = jnp.dot(h_last, _head_w(params, cfg).astype(h.dtype))
    return logits, new_caches


def lm_paged_verify(params, tokens, ctx_len, block_table, n_valid,
                    state_idx, caches, cfg: ArchConfig, rt: Runtime):
    """Score a speculation window in one paged forward pass (speculative
    decoding's verify step — serving/spec.py has the drafter).

    Same contract as ``lm_paged_step`` — ``tokens`` (B, C) is each row's
    next C tokens (here: the pending token plus up to C-1 draft tokens,
    padded past ``n_valid``), written to the pages and attended causally
    within the window through the chunked-prefill page-gather path — but
    logits come back at **every** window position, (B, C, V): position j
    is the model's distribution for the token *after* window token j,
    which is exactly what acceptance needs to compare draft j+1 against.
    C is the draft window (K+1, single-digit), so the (B, C, V) block
    stays tiny. Rows with ``n_valid`` < C carry garbage logits past their
    window — the engine only reads positions < n_valid.
    """
    x = embedding_apply(params["embed"], tokens)
    h, new_caches = stack_paged(params["stack"], x, ctx_len, block_table,
                                n_valid, state_idx, cfg, rt, caches)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    logits = jnp.dot(h, _head_w(params, cfg).astype(h.dtype))
    return logits, new_caches


def lm_paged_fused_step(params, tokens, ctx_len, block_table, n_valid,
                        state_idx, caches, cfg: ArchConfig, rt: Runtime):
    """One fused decode tick: plain decode (C == 1) *and* the speculative
    verify window (C == K+1) through the ragged decode megakernel — every
    layer's attention is ONE ``paged_decode_ragged`` launch over the
    batch's ragged (slot, attend_len) grid instead of a per-call kernel
    plus page gathers.

    Same contract as ``lm_paged_verify``: ``tokens`` (B, C) is each row's
    next window (pending token + drafts, padded past ``n_valid``), and
    logits come back at every window position, (B, C, V) — position j is
    the distribution for the token after window token j. With C == 1 the
    engine reads logits[:, 0] and this is exactly ``lm_paged_step``'s
    decode tick, so one compiled function serves both tick shapes.
    Rows past ``n_valid`` carry garbage logits (the kernel returns zeros
    for them pre-head) — the engine only reads positions < n_valid.
    """
    x = embedding_apply(params["embed"], tokens)
    h, new_caches = stack_paged(params["stack"], x, ctx_len, block_table,
                                n_valid, state_idx, cfg, rt, caches,
                                fused=True)
    h = norm_apply(cfg.norm, params["final_norm"], h)
    logits = jnp.dot(h, _head_w(params, cfg).astype(h.dtype))
    return logits, new_caches
