"""Shims over the jax API surface that moved between the versions we
support (see also launch/mesh.py for mesh-context shims)."""
from __future__ import annotations

import jax

__all__ = ["shard_map", "cost_analysis_dict", "pallas_compiler_params"]


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() returned a per-device list on older jax,
    a single dict on newer; normalize to the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return cost


def pallas_compiler_params(**kw):
    """pltpu.TPUCompilerParams was renamed CompilerParams; accept both."""
    import jax.experimental.pallas.tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or pltpu.TPUCompilerParams
    return cls(**kw)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """jax.shard_map moved out of jax.experimental and renamed its
    replication-check kwarg (check_rep -> check_vma); accept both worlds."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
