"""Property + unit tests for SPx quantization (paper §3.2, Eq. 3.1/3.3/3.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spx
from repro.core.quantized import dequantize, quantize_weight, ref_matmul

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Level-set structure (Eq. 3.1 / 3.3 / 3.4)
# ---------------------------------------------------------------------------

class TestLevelSets:
    @pytest.mark.parametrize("b", [2, 3, 4])
    def test_pot_levels_match_eq31(self, b):
        lv = spx.pot_levels(b)
        # Eq 3.1: {0} ∪ {±2^-e : e = 0..2^(b-1)-1}
        expect = {0.0} | {s * 0.5 ** e for e in range(2 ** (b - 1)) for s in (1, -1)}
        assert set(np.round(lv, 12)) == {round(v, 12) for v in expect}

    @pytest.mark.parametrize("tb", [(1,), (2, 1), (3, 3), (1, 1, 1), (2, 2, 1)])
    def test_spx_symmetric_sorted_normalized(self, tb):
        lv = spx.spx_levels(tb)
        assert np.all(np.diff(lv) > 0), "levels strictly sorted"
        np.testing.assert_allclose(lv, -lv[::-1], atol=0)
        assert 0.0 in lv and lv[-1] == 1.0 and lv[0] == -1.0

    def test_sp2_refines_pot_tail(self):
        """The paper's motivation: PoT is sparse near ±alpha; SP2 is denser.
        Compare the largest gap in the tail region [0.5, 1.0]."""
        def max_tail_gap(lv):
            tail = lv[lv >= 0.5]
            return np.max(np.diff(tail))
        pot = spx.pot_levels(4)
        sp2 = spx.sp2_levels(4)
        assert max_tail_gap(sp2) < max_tail_gap(pot)

    def test_spx_x3_refines_sp2_tail(self):
        """Eq. 3.4's extension: at matched code width (8 bits), x=3 places a
        larger FRACTION of its levels in the tail [0.5, 1] than SP2 — the
        'more choices at the two tail ends' the paper claims."""
        sp2 = spx.scheme_levels("sp2_8")      # (4,2), width 8
        sp3 = spx.scheme_levels("spx_8_x3")   # (3,2,2), width 8
        assert spx.code_width(sp2) == spx.code_width(sp3) == 8
        def tail_frac(lv):
            return np.sum((lv >= 0.5) & (lv <= 1.0)) / len(lv)
        assert tail_frac(sp3) > tail_frac(sp2)

    def test_code_width_all_schemes_le_8(self):
        for name in spx.SCHEMES:
            lv = spx.scheme_levels(name)
            assert spx.code_width(lv) <= 8, name

    def test_codebook_padded_pow2(self):
        for name in spx.SCHEMES:
            lut = spx.codebook(spx.scheme_levels(name))
            n = lut.shape[0]
            assert n & (n - 1) == 0


# ---------------------------------------------------------------------------
# Quantize/dequantize properties (hypothesis)
# ---------------------------------------------------------------------------

SCHEME_NAMES = sorted(spx.SCHEMES)


@st.composite
def arrays(draw, max_size=64):
    n = draw(st.integers(2, max_size))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(1e-3, 1e3))
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(np.float32)


class TestQuantizeProps:
    @settings(max_examples=40, deadline=None)
    @given(x=arrays(), scheme=st.sampled_from(SCHEME_NAMES))
    def test_error_bounded_by_half_max_gap(self, x, scheme):
        lv = spx.scheme_levels(scheme)
        alpha = spx.calibrate_minmax(jnp.asarray(x), channel_axis=None)
        xh = spx.fake_quantize(jnp.asarray(x), scheme, alpha)
        gap = np.max(np.diff(lv))
        err = np.abs(np.asarray(xh) - x)
        a = np.asarray(alpha).item()
        assert np.all(err <= a * gap / 2 + 1e-5 * a)

    @settings(max_examples=25, deadline=None)
    @given(x=arrays(), scheme=st.sampled_from(SCHEME_NAMES))
    def test_idempotent(self, x, scheme):
        """quantize(dequantize(quantize(x))) == quantize(x)."""
        alpha = spx.calibrate_minmax(jnp.asarray(x), channel_axis=None)
        lv = spx.scheme_levels(scheme)
        c1 = spx.quantize_to_codes(jnp.asarray(x), lv, alpha)
        xh = spx.dequantize_codes(c1, spx.codebook(lv), alpha, dtype=jnp.float32)
        c2 = spx.quantize_to_codes(xh, lv, alpha)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))

    @settings(max_examples=25, deadline=None)
    @given(x=arrays())
    def test_levels_are_fixed_points(self, x):
        """Exact level values quantize to themselves."""
        lv = spx.scheme_levels("sp2_4")
        vals = jnp.asarray(lv, jnp.float32)
        xh = spx.fake_quantize(vals, "sp2_4", jnp.asarray(1.0))
        np.testing.assert_allclose(np.asarray(xh), lv, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_mse_calibration_not_worse_than_minmax(self, seed):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((32, 48)).astype(np.float32)
        w = jnp.asarray(w)
        scheme = "sp2_4"
        a_mm = spx.calibrate_minmax(w, -1)
        a_mse = spx.calibrate_mse(w, scheme, -1)
        e_mm = jnp.mean((spx.fake_quantize(w, scheme, a_mm) - w) ** 2)
        e_mse = jnp.mean((spx.fake_quantize(w, scheme, a_mse) - w) ** 2)
        assert float(e_mse) <= float(e_mm) * (1 + 1e-6)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 32))
    def test_pack_unpack_roundtrip(self, seed, n):
        rng = np.random.default_rng(seed)
        codes = jnp.asarray(rng.integers(0, 16, size=(3, 2 * n)), jnp.uint8)
        np.testing.assert_array_equal(
            np.asarray(spx.unpack_int4(spx.pack_int4(codes))), np.asarray(codes))


# ---------------------------------------------------------------------------
# QuantizedTensor + ref matmul
# ---------------------------------------------------------------------------

class TestQuantizedTensor:
    def test_roundtrip_and_storage(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((64, 96)), jnp.float32)
        qt = quantize_weight(w, "sp2_4")
        assert qt.packed and qt.codes.shape == (64, 48)
        assert qt.nbytes_stored() < w.size * 4 / 6  # >6x smaller than f32
        wh = dequantize(qt, jnp.float32)
        rel = float(jnp.linalg.norm(wh - w) / jnp.linalg.norm(w))
        assert rel < 0.25  # 4-bit nonuniform: coarse but sane

    def test_8bit_tighter_than_4bit(self):
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
        def rel(scheme):
            qt = quantize_weight(w, scheme)
            return float(jnp.linalg.norm(dequantize(qt, jnp.float32) - w)
                         / jnp.linalg.norm(w))
        assert rel("sp2_8") < rel("sp2_4")

    def test_ref_matmul_matches_dequant_matmul(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        qt = quantize_weight(w, "sp2_8")
        got = ref_matmul(x, qt)
        want = x @ dequantize(qt, jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_pytree_flattens_through_jit(self):
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        qt = quantize_weight(w, "sp2_4")
        f = jax.jit(lambda x, q: ref_matmul(x, q))
        x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
        out = f(x, qt)
        assert out.shape == (4, 32)

    def test_quantized_matmul_snr(self):
        """End metric the paper cares about: matmul output fidelity."""
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.standard_normal((16, 256)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((256, 128)) * 0.05, jnp.float32)
        ref = x @ w
        for scheme, min_snr in [("sp2_8", 25.0), ("spx_8_x3", 25.0),
                                ("sp2_4", 8.0)]:
            qt = quantize_weight(w, scheme)
            out = ref_matmul(x, qt, out_dtype=jnp.float32)
            err = jnp.linalg.norm(out - ref)
            snr = 20 * jnp.log10(jnp.linalg.norm(ref) / (err + 1e-12))
            assert float(snr) > min_snr, (scheme, float(snr))


class TestPipelinePlan:
    def test_plan_fits_vmem_and_aligned(self):
        from repro.core import plan_matmul_blocks, TPU_V5E
        p = plan_matmul_blocks(4096, 4096, 4096, weight_bits=4)
        assert p.vmem_bytes <= TPU_V5E.vmem_bytes
        assert p.bm % 128 == 0 and p.bn % 128 == 0 and p.bk % 128 == 0

    def test_quantization_widens_pipeline_margin(self):
        """The two paper contributions compose: fewer weight bits -> load
        time shrinks -> pipeline margin grows (§3.1 condition easier)."""
        from repro.core import plan_matmul_blocks
        m16 = plan_matmul_blocks(8192, 8192, 8192, weight_bits=16)
        m4 = plan_matmul_blocks(8192, 8192, 8192, weight_bits=4)
        assert m4.margin >= m16.margin
