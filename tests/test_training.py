"""Training substrate: optimizers, checkpoint/restart (incl. fault
injection), gradient compression with error feedback, watchdog."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.mnist import SynthDigits
from repro.data.tokens import TokenStream
from repro.models.mlp_mnist import paper_mlp_init, paper_mlp_loss
from repro.training import (GradCompressor, StallDetected, StepWatchdog,
                            TrainConfig, TrainLoop, latest_step,
                            make_optimizer, restore_checkpoint,
                            save_checkpoint)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quad_problem(opt, steps=60):
    """Minimize ||x - target||^2; returns final distance."""
    target = jnp.asarray(np.linspace(-1, 1, 32), jnp.float32)
    params = {"x": jnp.zeros(32, jnp.float32)}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"x": 2 * (params["x"] - target)}
        params, state = opt.update(params, grads, state)
    return float(jnp.linalg.norm(params["x"] - target))


def test_sgd_and_adamw_converge():
    assert _quad_problem(make_optimizer("sgd", lr=0.1)) < 1e-3
    # constant-LR Adam oscillates near the optimum; 0.05 distance on a
    # unit-scale target is converged for this purpose
    assert _quad_problem(make_optimizer("adamw", lr=0.1), 400) < 0.05


def test_adamw_q8_tracks_adamw():
    """Quantized-moment AdamW lands near plain AdamW on a quadratic."""
    d_q8 = _quad_problem(make_optimizer("adamw_q8", lr=0.1), 200)
    d_fp = _quad_problem(make_optimizer("adamw", lr=0.1), 200)
    assert d_q8 < max(10 * d_fp, 0.15), (d_q8, d_fp)


def test_adamw_q8_state_is_uint8():
    opt = make_optimizer("adamw_q8", lr=1e-3)
    params = {"w": jnp.zeros((8, 16), jnp.float32)}
    st = opt.init(params)
    assert st["mu"]["w"]["codes"].dtype == jnp.uint8
    assert st["nu"]["w"]["codes"].dtype == jnp.uint8


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_prune(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones(4), {"c": jnp.zeros((2, 2), jnp.bfloat16)}]}
    for step in (10, 20, 30, 40):
        save_checkpoint(str(tmp_path), step, tree, keep=2)
    assert latest_step(str(tmp_path)) == 40
    restored, step, _ = restore_checkpoint(str(tmp_path), tree)
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"][1]["c"].dtype == jnp.bfloat16
    # pruned to keep=2
    from repro.training import list_checkpoints
    assert list_checkpoints(str(tmp_path)) == [30, 40]


def test_checkpoint_template_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"zz": jnp.zeros(3)})


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint saved unsharded restores under a different device layout
    (single CPU device acts as the 'new mesh')."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 5, tree)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import mesh_axis_kwargs
    mesh = jax.make_mesh((1,), ("data",), **mesh_axis_kwargs(1))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    restored, step, _ = restore_checkpoint(str(tmp_path), tree, shardings=sh)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# Fault tolerance: kill + resume reproduces uninterrupted training
# ---------------------------------------------------------------------------

def _mlp_loop(tmp_path, kill_at, max_steps, seed=0):
    data = SynthDigits(n_train=512, n_test=64, batch_size=32, seed=seed)
    it = iter_batches(data)
    cfg = TrainConfig(max_steps=max_steps, ckpt_dir=str(tmp_path),
                      ckpt_every=5, log_every=1000, kill_at_step=kill_at)
    loop = TrainLoop(
        loss_fn=lambda p, b: (paper_mlp_loss(p, b["x"], b["y"]), {}),
        opt=make_optimizer("sgd", lr=0.5),
        init_params_fn=lambda: paper_mlp_init(jax.random.PRNGKey(seed)),
        data_iter=it, cfg=cfg)
    return loop


def iter_batches(data):
    while True:
        for x, y in data.batches(epochs=1000):
            yield {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def test_kill_and_resume(tmp_path):
    loop = _mlp_loop(tmp_path, kill_at=12, max_steps=20)
    with pytest.raises(KeyboardInterrupt):
        loop.run()
    assert latest_step(str(tmp_path)) == 10  # last periodic ckpt before kill
    # resume: a fresh loop picks up at 10 and finishes
    loop2 = _mlp_loop(tmp_path, kill_at=None, max_steps=20)
    params, hist = loop2.run()
    assert hist[-1]["step"] == 20
    assert latest_step(str(tmp_path)) == 20


def test_loss_decreases_on_synth_mnist(tmp_path):
    loop = _mlp_loop(tmp_path, kill_at=None, max_steps=60, seed=1)
    params, hist = loop.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.8, (first, last)


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------

def test_watchdog_flags_straggler():
    wd = StepWatchdog(stall_factor=3.0, warmup=2, min_stall_s=0.0)
    for _ in range(5):
        wd.observe(0.1)
    with pytest.raises(StallDetected):
        wd.observe(1.0)
    assert wd.stalls == 1


# ---------------------------------------------------------------------------
# Gradient compression (error feedback)
# ---------------------------------------------------------------------------

def test_compression_error_feedback_reduces_bias():
    """With EF, the time-average of compressed grads tracks the true grad
    far better than one-shot quantization."""
    comp = GradCompressor("sp2_4", min_size=1)
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((4, 4096)) * 1e-3, jnp.float32)
    ef = comp.init({"g": g_true})
    acc = jnp.zeros_like(g_true)
    N = 24
    for _ in range(N):
        gq, ef = comp.compress({"g": g_true}, ef)
        acc = acc + gq["g"]
    avg_err_ef = float(jnp.linalg.norm(acc / N - g_true)
                       / jnp.linalg.norm(g_true))
    gq1, _ = comp.compress({"g": g_true}, comp.init({"g": g_true}))
    one_shot_err = float(jnp.linalg.norm(gq1["g"] - g_true)
                         / jnp.linalg.norm(g_true))
    assert avg_err_ef < one_shot_err * 0.5, (avg_err_ef, one_shot_err)


def test_compressed_training_still_converges():
    comp = GradCompressor("sp2_8", min_size=1)
    opt = make_optimizer("sgd", lr=0.1)
    target = jnp.asarray(np.linspace(-1, 1, 64), jnp.float32)
    params = {"x": jnp.zeros(64, jnp.float32)}
    state = opt.init(params)
    ef = comp.init(params)
    for _ in range(80):
        grads = {"x": 2 * (params["x"] - target)}
        grads, ef = comp.compress(grads, ef)
        params, state = opt.update(params, grads, state)
    assert float(jnp.linalg.norm(params["x"] - target)) < 0.05
