"""Per-request token streaming + cancellation (serving/stream.py):
stream-vs-run() bit-identity across the paged x SPx x spec x fused x cb
matrix, cancellation at every tick-boundary class with a clean pool
``validate()`` after each, the monotonic fake-clock regression, the
submit-reuse regression, the strict-run stream sentinel, and the asyncio
consumption path.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving import engine as engine_mod
from repro.serving.engine import Request, ServeConfig, ServeEngine
from repro.serving.kv_cache import StateCache
from repro.serving.stream import StreamCancelled, StreamError

jax.config.update("jax_platform_name", "cpu")

# same pinned geometry as tests/test_scheduler.py: vocab=32 keeps top-2
# logit gaps wide so exact-output asserts don't flip on near-ties
CFG = reduced(get_config("gemma-2b"), vocab=32)
RT = Runtime(impl="ref", q_chunk=16)
RT_Q = RT.replace(kv_quant=True, kv_scheme="spx_8_x3")

PAGE = 8
POOL = 8
SLOTS = 2
MAX_SEQ = 48


@pytest.fixture(scope="module")
def params():
    return lm_mod.lm_init(jax.random.PRNGKey(3), CFG)


def _prompts(seed=3, n=4):
    """Mixed-length prompts with repetitive tails, so spec combos
    actually draft instead of degrading to plain decode."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        pat = rng.integers(1, CFG.vocab_size, 3).astype(np.int32)
        out.append(np.tile(pat, int(rng.integers(2, 5))))
    return out


def _engine(params, *, kvq=False, prefix=False, spec=False, fused=True,
            scheduler="cb", layout="paged"):
    return ServeEngine(params, CFG,
                       ServeConfig(batch_slots=SLOTS, max_seq=MAX_SEQ,
                                   quantize=None, kv_layout=layout,
                                   **({} if layout == "dense"
                                      else dict(page_size=PAGE,
                                                pool_pages=POOL,
                                                scheduler=scheduler,
                                                prefix_cache=prefix,
                                                spec_decode=spec,
                                                spec_k=3 if spec else None,
                                                fused_decode=fused))),
                       rt=RT_Q if kvq else RT)


def _submit_all(eng, new_tokens=6):
    for i, p in enumerate(_prompts()):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))


# ---------------------------------------------------------------------------
# Stream-vs-run() bit-identity across the feature matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kvq,prefix,spec,fused,scheduler", [
    (False, False, False, True, "cb"),
    (False, False, False, True, "fifo"),
    (True, False, False, True, "cb"),
    (False, True, False, True, "cb"),
    (False, False, True, True, "cb"),
    (False, False, True, False, "cb"),
    (True, True, True, True, "cb"),
])
def test_stream_matches_run(params, kvq, prefix, spec, fused, scheduler):
    """Delivered token sequences are bit-identical to run() results —
    streams read Request.output behind a cursor, so this pins that the
    read path stays pure across every engine feature combination."""
    ref = _engine(params, kvq=kvq, prefix=prefix, spec=spec, fused=fused,
                  scheduler=scheduler)
    _submit_all(ref)
    base = {r.rid: list(r.output) for r in ref.run(max_steps=500)}

    eng = _engine(params, kvq=kvq, prefix=prefix, spec=spec, fused=fused,
                  scheduler=scheduler)
    _submit_all(eng)
    streams = {i: eng.stream(i) for i in range(4)}
    # interleaved consumption: one token per stream round-robin, so the
    # consumers pull across requests while the engine is mid-flight
    got = {i: [] for i in range(4)}
    live = set(got)
    while live:
        for i in sorted(live):
            try:
                got[i].append(next(streams[i]))
            except StopIteration:
                live.discard(i)
    assert got == base
    eng.pool.validate()
    # a second stream over a finished request replays the full output
    assert list(eng.stream(2)) == base[2]


def test_stream_matches_run_dense(params):
    """The delivery surface is layout-agnostic: dense engines stream
    through the same state machine."""
    ref = _engine(params, layout="dense")
    _submit_all(ref)
    base = {r.rid: list(r.output) for r in ref.run(max_steps=500)}
    eng = _engine(params, layout="dense")
    _submit_all(eng)
    assert {i: list(eng.stream(i)) for i in range(4)} == base


def test_stream_unknown_rid(params):
    eng = _engine(params)
    with pytest.raises(KeyError):
        eng.stream(99)


# ---------------------------------------------------------------------------
# Cancellation at every tick-boundary class
# ---------------------------------------------------------------------------

def _assert_clean(eng):
    """No leaked pages/slabs/host entries after everything drained."""
    eng.pool.validate()
    st = eng.pool.stats
    assert st.pages_in_use == 0
    assert st.slabs_in_use == 0
    assert st.host_pages_in_use == 0


def test_cancel_queued(params):
    """Cancel a request still waiting in the queue (never admitted)."""
    eng = _engine(params)
    _submit_all(eng)                    # 4 requests through 2 slots
    assert eng.cancel(3) is True        # back of the queue
    eng.pool.validate()
    done = eng.run(max_steps=500)
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert eng.metrics()["requests_cancelled"] == 1
    _assert_clean(eng)
    with pytest.raises(StreamCancelled):
        list(eng.stream(3))


def test_cancel_mid_prefill(params):
    """Cancel a resident slot that is still feeding prompt chunks."""
    eng = ServeEngine(params, CFG,
                      ServeConfig(batch_slots=SLOTS, max_seq=MAX_SEQ,
                                  quantize=None, kv_layout="paged",
                                  page_size=PAGE, pool_pages=POOL,
                                  prefill_chunk=4),
                      rt=RT)
    rng = np.random.default_rng(0)
    long_prompt = rng.integers(1, CFG.vocab_size, 20).astype(np.int32)
    eng.submit(Request(rid=0, prompt=long_prompt, max_new_tokens=4))
    eng.step()                          # one 4-token chunk of 20 fed
    assert eng._fed[0] >= 0, "request should still be prefilling"
    assert eng.cancel(0) is True
    _assert_clean(eng)
    assert not eng.has_work()


def test_cancel_mid_decode_and_verify(params):
    """Cancel requests that already emitted tokens — one on a plain
    decode engine, one mid-verify on a speculative engine."""
    for spec in (False, True):
        eng = _engine(params, spec=spec)
        _submit_all(eng, new_tokens=8)
        while not any(len(r.output) for r in eng.slot_req
                      if r is not None):
            eng.step()
        rid = next(r.rid for r in eng.slot_req
                   if r is not None and len(r.output))
        assert eng.cancel(rid) is True
        eng.pool.validate()
        done = eng.run(max_steps=500)
        assert rid not in {r.rid for r in done}
        _assert_clean(eng)


def test_cancel_preempted_and_parked(params):
    """Cancel a request parked on the host tier mid-preemption: the
    host entry (snapshot payload + page accounting) must drop."""
    eng = _engine(params)
    _submit_all(eng, new_tokens=8)
    while not any(len(r.output) for r in eng.slot_req if r is not None):
        eng.step()
    rid = next(r.rid for r in eng.slot_req
               if r is not None and len(r.output))
    eng.preempt(rid)                    # fault injection: park it
    assert eng.pool.host_resident(rid)
    assert eng.pool.stats.host_pages_in_use > 0
    assert eng.cancel(rid) is True
    assert not eng.pool.host_resident(rid)
    assert eng.pool.stats.host_pages_in_use == 0
    eng.pool.validate()
    done = eng.run(max_steps=500)
    assert rid not in {r.rid for r in done}
    _assert_clean(eng)


def test_cancel_terminal_and_unknown(params):
    eng = _engine(params)
    _submit_all(eng)
    eng.run(max_steps=500)
    assert eng.cancel(0) is False       # already finished
    with pytest.raises(KeyError):
        eng.cancel(99)                  # never submitted
    eng2 = _engine(params)
    _submit_all(eng2)
    assert eng2.cancel(1) is True
    assert eng2.cancel(1) is False      # double cancel: no live work


def test_drop_host_pool_level():
    """StateCache.drop_host releases the host entry AND the cross
    reference offload deliberately retained (a parked sequence keeps
    its share of the encoder output; a cancelled one must not)."""
    pool = StateCache(8, 4, n_slabs=2, n_cross=2, host_pages=8)
    key = b"frames-0"
    assert pool.allocate(0, 8, need_slab=True, cross_key=key) is not None
    assert pool.allocate(1, 8, need_slab=True, cross_key=key) is not None
    assert pool.stats.cross_in_use == 1          # shared entry
    assert pool.offload(0, 2, payload="snap") is not None
    assert pool.seq_cross(0) is not None         # ref survives parking
    assert pool.stats.slabs_in_use == 1          # slab went back
    pool.validate()
    assert pool.drop_host(0) == 2
    assert pool.seq_cross(0) is None
    assert pool.stats.host_pages_in_use == 0
    assert pool.stats.cross_in_use == 1          # seq 1 still holds it
    pool.validate()
    pool.release(1)
    assert pool.stats.cross_in_use == 0          # cached-free now
    pool.validate()
    with pytest.raises(KeyError):
        pool.drop_host(0)                        # not parked anymore


# ---------------------------------------------------------------------------
# Monotonic clock: fake-clock regression + no wall-clock in the suite
# ---------------------------------------------------------------------------

def test_fake_clock_latencies(params, monkeypatch):
    """Every engine timestamp flows through the engine._now hook: under
    a fake counter clock the latency metrics are exact tick counts —
    and can never go negative, the bug wall-clock time.time() had."""
    t = {"now": 0.0}

    def fake_now():
        t["now"] += 1.0
        return t["now"]

    monkeypatch.setattr(engine_mod, "_now", fake_now)
    eng = _engine(params)
    _submit_all(eng)
    done = eng.run(max_steps=500)
    assert len(done) == 4
    for r in done:
        assert r.t_enqueue > 0
        assert r.t_first_token > r.t_enqueue
        assert r.t_done >= r.t_first_token
    m = eng.metrics()
    assert m["ttft_p50_ms"] > 0
    assert m["latency_p95_ms"] >= m["latency_p50_ms"] > 0
    assert m["wall_s"] > 0


def test_default_clock_is_monotonic():
    import time
    assert engine_mod._now is time.monotonic


def test_no_wall_clock_in_timing_code():
    """No metric in the suite may derive from time.time(): scan every
    timing-bearing source tree for the call (comments excluded)."""
    import os
    root = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
    offenders = []
    for sub in ("src/repro/serving", "src/repro/launch",
                "src/repro/training", "benchmarks", "examples"):
        for dirpath, _dirs, files in os.walk(os.path.join(root, sub)):
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path) as fh:
                    for ln, line in enumerate(fh, 1):
                        if line.split("#", 1)[0].count("time.time()"):
                            offenders.append(f"{path}:{ln}")
    assert not offenders, f"wall-clock timing sites: {offenders}"


# ---------------------------------------------------------------------------
# submit() reuse hardening
# ---------------------------------------------------------------------------

def test_submit_rejects_served_request_object(params):
    eng = _engine(params)
    _submit_all(eng)
    done = eng.run(max_steps=500)
    with pytest.raises(ValueError, match="already .* served|already"):
        eng.submit(done[0])             # stale PRNG chain + timestamps


def test_submit_rejects_finished_rid(params):
    eng = _engine(params)
    _submit_all(eng)
    eng.run(max_steps=500)
    with pytest.raises(ValueError, match="finished"):
        eng.submit(Request(rid=0, prompt=_prompts()[0],
                           max_new_tokens=4))
    # the benchmark warmup pattern stays legal: reset, then fresh
    # Request objects may reuse the rids
    eng.reset_metrics()
    eng.submit(Request(rid=0, prompt=_prompts()[0], max_new_tokens=4))
    assert len(eng.run(max_steps=500)) == 1


def test_resubmit_after_cancel_gets_fresh_stream(params):
    """A cancelled rid may be resubmitted (fresh Request object): the
    new submission binds a new stream state, and streams opened on the
    cancelled one stay terminal."""
    eng = _engine(params)
    _submit_all(eng)
    eng.cancel(3)
    old = eng.stream(3)
    eng.submit(Request(rid=3, prompt=_prompts()[3], max_new_tokens=6))
    done = eng.run(max_steps=500)
    assert 3 in {r.rid for r in done}
    assert len(list(eng.stream(3))) == 6     # the new request's tokens
    with pytest.raises(StreamCancelled):
        list(old)                            # the old state is terminal


# ---------------------------------------------------------------------------
# strict-run stream sentinel
# ---------------------------------------------------------------------------

def test_strict_run_fails_streams(params):
    """run(strict=True) hitting max_steps with live work must leave
    pending streams in a terminal error state, not hanging forever."""
    eng = _engine(params)
    _submit_all(eng, new_tokens=16)
    s = eng.stream(0)
    with pytest.raises(RuntimeError, match="live work"):
        eng.run(max_steps=2)
    with pytest.raises(StreamError):
        list(s)
    # the error state also wakes async consumers
    async def consume():
        async for _ in eng.stream(1):
            pass
    with pytest.raises(StreamError):
        asyncio.run(consume())


# ---------------------------------------------------------------------------
# asyncio consumption
# ---------------------------------------------------------------------------

def test_async_stream_matches_run(params):
    ref = _engine(params)
    _submit_all(ref)
    base = {r.rid: list(r.output) for r in ref.run(max_steps=500)}

    eng = _engine(params)

    async def amain():
        _submit_all(eng)

        async def consume(i):
            toks = []
            async for tok in eng.stream(i):
                toks.append(tok)
            return toks

        async def drive():
            while eng.has_work():
                eng.step()
                await asyncio.sleep(0)

        res = await asyncio.gather(drive(),
                                   *[consume(i) for i in range(4)])
        return {i: res[1 + i] for i in range(4)}

    assert asyncio.run(amain()) == base


def test_async_cancel_wakes_consumer(params):
    eng = _engine(params)

    async def amain():
        _submit_all(eng)

        async def consume():
            async for _ in eng.stream(3):
                pass

        task = asyncio.ensure_future(consume())
        await asyncio.sleep(0)          # let the consumer park
        eng.cancel(3)
        with pytest.raises(StreamCancelled):
            await task
        while eng.has_work():
            eng.step()
            await asyncio.sleep(0)

    asyncio.run(amain())
    assert sorted(r.rid for r in eng.finished) == [0, 1, 2]
    _assert_clean(eng)
