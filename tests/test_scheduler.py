"""Continuous-batching scheduler: differential request-storm replay
(FIFO synchronous engine vs cb scheduler, bit-identical greedy outputs
across the paged x SPx-quant x prefix-cache x spec-decode x fused-decode
matrix), fault-injected preemption at every tick-boundary class, the
run()-undrained regression, and scheduler knob validation.

The differential harness is the PR's acceptance instrument: a seeded
workload (low-priority background requests that fill the page pool, a
high-priority burst arriving mid-run that must preempt them, a straggler)
replayed through both schedulers. The cb engine preempts, offloads KV to
the host tier and resumes from the exact write cursor — and every
request's greedy output must still be byte-for-byte what the synchronous
FIFO engine produced.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm as lm_mod
from repro.runtime import Runtime, planner
from repro.serving.engine import Request, ServeConfig, ServeEngine

jax.config.update("jax_platform_name", "cpu")

# vocab=32 keeps top-2 logit gaps wide relative to quantization error so
# exact-output asserts don't flip on near-ties (same rationale as the
# pinned bench workload in benchmarks/serving_bench.py)
CFG = reduced(get_config("gemma-2b"), vocab=32)
RT = Runtime(impl="ref", q_chunk=16)
RT_Q = RT.replace(kv_quant=True, kv_scheme="spx_8_x3")

PAGE = 8
POOL = 8          # two background requests fill it exactly
SLOTS = 2
MAX_SEQ = 48


@pytest.fixture(scope="module")
def params():
    return lm_mod.lm_init(jax.random.PRNGKey(3), CFG)


# ---------------------------------------------------------------------------
# Seeded request storm: the shared differential workload
# ---------------------------------------------------------------------------

def _rep_tail(rng, n):
    """Repetitive token tail so the prompt-lookup drafter actually
    drafts — a fresh-random tail would make every spec combo degrade to
    plain decode and test nothing."""
    pat = rng.integers(1, CFG.vocab_size, 3).astype(np.int32)
    return np.tile(pat, -(-n // 3))[:n]


def _storm(seed=7):
    """(rid, prompt, max_new, priority, arrival_tick) tuples. Background
    requests (priority 0) reserve 4 pages each — 2 x 4 fills the 8-page
    pool — so the priority-5 burst arriving at tick 3 cannot be admitted
    without preempting one of them."""
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(1, CFG.vocab_size, PAGE).astype(np.int32)

    def mk(n_tail):
        return np.concatenate([sys_p, _rep_tail(rng, n_tail)])

    reqs = [
        (0, mk(18), 6, 0, 0),       # background: 26 + 6 = 32 tok, 4 pages
        (1, mk(18), 6, 0, 0),       # background: 4 pages
        (2, mk(7), 4, 5, 3),        # burst: must preempt
        (3, mk(9), 4, 5, 3),
        (4, mk(11), 4, 5, 4),
        (5, mk(10), 4, 1, 6),       # straggler between the classes
    ]
    return [(rid, p, mn, pri, arr) for rid, p, mn, pri, arr in reqs]


def _run_fifo(params, rt):
    """The synchronous baseline: everything submitted up front in rid
    order, default knobs — the engine the tentpole replaced."""
    eng = ServeEngine(params, CFG,
                      ServeConfig(batch_slots=SLOTS, max_seq=MAX_SEQ,
                                  quantize=None, kv_layout="paged",
                                  page_size=PAGE, pool_pages=POOL,
                                  scheduler="fifo"),
                      rt=rt)
    for rid, prompt, max_new, _pri, _arr in _storm():
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
    eng.run(max_steps=500)
    assert eng.drained
    return {r.rid: list(r.output) for r in eng.finished}


def _run_cb(params, rt, *, prefix, spec, fused):
    eng = ServeEngine(params, CFG,
                      ServeConfig(batch_slots=SLOTS, max_seq=MAX_SEQ,
                                  quantize=None, kv_layout="paged",
                                  page_size=PAGE, pool_pages=POOL,
                                  scheduler="cb", prefix_cache=prefix,
                                  spec_decode=spec, spec_k=3 if spec else None,
                                  fused_decode=fused),
                      rt=rt)
    pending = sorted(_storm(), key=lambda r: r[4])
    for t in range(500):
        while pending and pending[0][4] <= t:
            rid, prompt, max_new, pri, _arr = pending.pop(0)
            eng.submit(Request(rid=rid, prompt=prompt,
                               max_new_tokens=max_new, priority=pri))
        if not pending and not eng.queue \
                and all(r is None for r in eng.slot_req):
            break
        eng.step()
    else:
        pytest.fail("cb storm did not drain in 500 ticks")
    eng.pool.validate()
    return eng, {r.rid: list(r.output) for r in eng.finished}


_BASELINE = {}


def _baseline(params, kvq):
    if kvq not in _BASELINE:
        _BASELINE[kvq] = _run_fifo(params, RT_Q if kvq else RT)
    return _BASELINE[kvq]


@pytest.mark.parametrize("kvq", [False, True], ids=["f32", "spx"])
@pytest.mark.parametrize("prefix", [False, True], ids=["npx", "pfx"])
@pytest.mark.parametrize("spec", [False, True], ids=["nsp", "spec"])
@pytest.mark.parametrize("fused", [False, True], ids=["unf", "fused"])
def test_storm_differential_cb_vs_fifo(params, kvq, prefix, spec, fused):
    """The tentpole acceptance: the same seeded storm through the old
    synchronous FIFO engine and the continuous-batching scheduler yields
    bit-identical per-request greedy outputs in every cell of the
    feature matrix — while the cb run actually preempts and offloads."""
    rt = RT_Q if kvq else RT
    eng, got = _run_cb(params, rt, prefix=prefix, spec=spec, fused=fused)
    assert got == _baseline(params, kvq)
    m = eng.metrics()
    assert m["preemptions"] > 0, "storm was not oversubscribed enough"
    assert m["resumes"] > 0
    assert m["offload_bytes"] > 0 and m["onload_bytes"] > 0
    assert m["offload_bytes"] == m["onload_bytes"]  # all victims resumed
    assert m["host_pages_in_use"] == 0              # drained -> host empty
    victims = [r for r in eng.finished if r.preemptions > 0]
    assert victims and all(r.priority == 0 for r in victims), \
        "only strictly-lower-priority residents may be preempted"


def test_storm_priority_ordering(params):
    """Scheduling-quality (not correctness) claims on the plain combo:
    the preempted victim resumes only after burst work drains, so the
    first burst request finishes before it; offload traffic is exactly
    the pages covering the victim's write cursor."""
    eng, _ = _run_cb(params, RT, prefix=False, spec=False, fused=True)
    order = [r.rid for r in eng.finished]
    victim = next(r for r in eng.finished if r.preemptions > 0)
    burst_first = min(order.index(rid) for rid in (2, 3, 4))
    assert burst_first < order.index(victim.rid)
    # every burst request beat the straggler to admission despite the
    # straggler's earlier priority class being lower, never preempted
    straggler = next(r for r in eng.finished if r.rid == 5)
    assert straggler.preemptions == 0


# ---------------------------------------------------------------------------
# Satellite: fault-injected preemption at every tick-boundary class
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kvq", [False, True], ids=["plain", "spx"])
def test_preemption_every_tick_boundary_bit_identical(params, kvq):
    """Force a preempt/resume cycle at EVERY tick boundary of a request's
    lifetime — mid-prefill chunk, mid-spec verify window (the write
    cursor sits behind rejected-draft garbage), page-boundary write
    (cursor exactly on a page edge) — and assert the resumed output is
    bit-identical to the un-preempted run. One engine per pool flavour,
    reused across injections so the jit cache pays once."""
    rt = RT_Q if kvq else RT
    eng = ServeEngine(params, CFG,
                      ServeConfig(batch_slots=2, max_seq=48, quantize=None,
                                  kv_layout="paged", page_size=4,
                                  prefill_chunk=4, pool_pages=12,
                                  scheduler="cb", spec_decode=True, spec_k=3),
                      rt=rt)
    rng = np.random.default_rng(11)
    prompt = np.concatenate([rng.integers(1, CFG.vocab_size, 4)
                             .astype(np.int32), _rep_tail(rng, 6)])

    def run_once(rid, t_preempt):
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=8))
        classes = set()
        for t in range(200):
            if t == t_preempt:
                slot = next((i for i, r in enumerate(eng.slot_req)
                             if r is not None and r.rid == rid), None)
                if slot is not None:
                    fed = int(eng._fed[slot])
                    pos = int(eng.slot_pos[slot])
                    if fed >= 0:
                        classes.add("mid-prefill")
                    else:
                        classes.add("mid-spec-window")
                    if pos > 0 and pos % eng.page_size == 0:
                        classes.add("page-boundary")
                    eng.preempt(rid)
            if not eng.queue and all(r is None for r in eng.slot_req):
                break
            eng.step()
        else:
            pytest.fail("injected run did not drain")
        eng.pool.validate()
        done = {r.rid: list(r.output) for r in eng.finished}
        return done[rid], classes

    base, _ = run_once(0, -1)
    assert len(base) == 8
    covered = set()
    for t in range(1, 13):
        out, classes = run_once(100 + t, t)
        assert out == base, f"preemption at tick {t} changed the output"
        covered |= classes
    assert {"mid-prefill", "mid-spec-window", "page-boundary"} <= covered, \
        f"injection sweep missed a boundary class: {covered}"


# ---------------------------------------------------------------------------
# Satellite: run() surfaces undrained work instead of dropping it
# ---------------------------------------------------------------------------

def test_run_surfaces_undrained_work(params):
    """run() hitting max_steps with live requests used to return
    silently. Now: RuntimeError under strict (the default), drained flag
    + undrained_runs metric either way, and no work is lost — a later
    run() finishes exactly the tokens the request asked for."""
    eng = ServeEngine(params, CFG,
                      ServeConfig(batch_slots=1, max_seq=48, quantize=None,
                                  kv_layout="paged", page_size=8,
                                  prefill_chunk=4, scheduler="cb"),
                      rt=RT)
    prompt = np.arange(1, 13, dtype=np.int32)       # 3 prefill chunks
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    with pytest.raises(RuntimeError, match="live work"):
        eng.run(max_steps=2)
    assert eng.drained is False
    assert eng.metrics()["undrained_runs"] == 1
    partial = eng.run(max_steps=1, strict=False)    # no raise, flagged
    assert partial == [] and eng.drained is False
    assert eng.metrics()["undrained_runs"] == 2
    done = eng.run()                                # drains clean
    assert eng.drained is True
    assert eng.metrics()["undrained_runs"] == 2
    assert len(done) == 1 and len(done[0].output) == 8


# ---------------------------------------------------------------------------
# Satellite: knob validation + the resume reservation model
# ---------------------------------------------------------------------------

def test_scheduler_knob_validation(params):
    mk = lambda **kw: ServeEngine(params, CFG,
                                  ServeConfig(batch_slots=1, max_seq=32,
                                              quantize=None, **kw),
                                  rt=RT)
    with pytest.raises(ValueError, match="fifo.*cb|'fifo' or 'cb'"):
        mk(scheduler="lifo")
    # explicit cb / tier knobs on a dense engine are caller errors
    with pytest.raises(ValueError, match="needs kv_layout='paged'"):
        mk(kv_layout="dense", scheduler="cb")
    with pytest.raises(ValueError, match="need kv_layout='paged'"):
        mk(kv_layout="dense", host_pages=4)
    with pytest.raises(ValueError, match="need kv_layout='paged'"):
        mk(kv_layout="dense", prefix_cache_pages=4)
    # dense engines run the fifo scheduler and say so
    dense = mk(kv_layout="dense")
    assert dense.scheduler == "fifo"
    assert dense.metrics()["scheduler"] == "fifo"
    # paged default is cb; preempting a non-resident rid is an error
    paged = mk(kv_layout="paged", page_size=8)
    assert paged.scheduler == "cb"
    with pytest.raises(KeyError, match="not resident"):
        paged.preempt(99)


def test_plan_resume_pages_model():
    # full reservation + restored prefix, page-rounded independently
    assert planner.plan_resume_pages(0, 32, 8) == (4, 0)
    assert planner.plan_resume_pages(9, 32, 8) == (4, 2)
    assert planner.plan_resume_pages(32, 32, 8) == (4, 4)
    with pytest.raises(ValueError):
        planner.plan_resume_pages(33, 32, 8)
    with pytest.raises(ValueError):
        planner.plan_resume_pages(-1, 32, 8)
