"""tools/ci_shards.py is the single source of truth for the tier-1 CI
shards: the map must be disjoint and exhaustive over tests/test_*.py, a
deliberately omitted file must fail --check (that is the whole point —
a new test file can't silently drop out of CI), and the workflow must
actually consume its ignore lists."""
import os
import subprocess
import sys

_TOOLS = os.path.normpath(os.path.join(os.path.dirname(__file__), "..",
                                       "tools"))
sys.path.insert(0, _TOOLS)

import ci_shards  # noqa: E402


def test_real_map_is_disjoint_and_exhaustive():
    assert ci_shards.check() == []


def test_every_shard_ignores_exactly_the_other_shards():
    all_files = {f for files in ci_shards.SHARDS.values() for f in files}
    for shard, files in ci_shards.SHARDS.items():
        ignored = {a.removeprefix("--ignore=")
                   for a in ci_shards.ignore_args(shard)}
        assert ignored == all_files - set(files), shard
        assert not ignored & set(files), shard    # never ignores its own


def test_omitted_file_fails_check():
    # drop one file from every shard: --check must name it
    broken = {name: [f for f in files if f != "tests/test_serving.py"]
              for name, files in ci_shards.SHARDS.items()}
    failures = ci_shards.check(shards=broken)
    assert any("tests/test_serving.py" in m and "not assigned" in m
               for m in failures), failures


def test_double_assignment_and_stale_entry_fail_check():
    dup = {"a": ["tests/test_serving.py"], "b": ["tests/test_serving.py"]}
    assert any("disjoint" in m
               for m in ci_shards.check(
                   shards=dup, test_files=["tests/test_serving.py"]))
    stale = {"a": ["tests/test_serving.py", "tests/test_gone.py"]}
    assert any("not on disk" in m
               for m in ci_shards.check(
                   shards=stale, test_files=["tests/test_serving.py"]))


def test_unknown_shard_raises():
    try:
        ci_shards.ignore_args("no-such-shard")
    except KeyError as e:
        assert "no-such-shard" in str(e)
    else:
        raise AssertionError("expected KeyError")


def test_cli_check_and_ignore_args():
    script = os.path.join(_TOOLS, "ci_shards.py")
    ok = subprocess.run([sys.executable, script, "--check"],
                       capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    out = subprocess.run([sys.executable, script, "--ignore-args", "core"],
                         capture_output=True, text=True)
    assert out.returncode == 0
    args = out.stdout.split()
    assert args and all(a.startswith("--ignore=tests/test_") for a in args)
    bad = subprocess.run([sys.executable, script, "--ignore-args", "nope"],
                         capture_output=True, text=True)
    assert bad.returncode == 1


def test_workflow_consumes_the_shard_map():
    """ci.yml must build its pytest args from ci_shards.py (no more
    hand-duplicated ignore lists) and run --check in the checks job; the
    matrix must name exactly the shards the map defines."""
    wf = open(os.path.join(ci_shards.REPO, ".github", "workflows",
                           "ci.yml")).read()
    assert "ci_shards.py --check" in wf
    assert "ci_shards.py --ignore-args" in wf
    assert "--ignore=tests/" not in wf      # the old hand-written lists
    matrix = [ln for ln in wf.splitlines()
              if ln.strip().startswith("shard: [")]
    assert len(matrix) == 1
    names = {s.strip() for s in
             matrix[0].split("[", 1)[1].rstrip(" ]").split(",")}
    assert names == set(ci_shards.SHARDS)
