"""MoE unit tests: capacity dispatch correctness, shared expert, aux loss,
and equivalence of the local path against a dense (loop-over-experts)
oracle when capacity is unconstrained."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import moe
from repro.runtime import Runtime

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref")


def _setup(seed=0, d=16, f=32, e=4, t=24):
    key = jax.random.PRNGKey(seed)
    p = moe.moe_init(key, d, f, e)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, t, d))
    return p, x, (d, f, e, t)


def _dense_oracle(p, x, top_k, n_experts):
    """Loop over experts; every token processed by its top-k experts with
    renormalized gates (no capacity)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for e in range(n_experts):
        h = jax.nn.silu(xf @ p["gate"][e]) * (xf @ p["up"][e])
        ye = h @ p["down"][e]
        w = jnp.sum(jnp.where(top_e == e, top_p, 0.0), axis=-1)
        y = y + ye * w[:, None]
    return y.reshape(b, s, d)


@pytest.mark.parametrize("top_k", [1, 2])
def test_moe_matches_dense_oracle_unconstrained(top_k):
    p, x, (d, f, e, t) = _setup()
    y, aux = moe.moe_apply(p, x, top_k=top_k, n_experts=e,
                           capacity_factor=64.0, rt=RT)
    want = _dense_oracle(p, x, top_k, e)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 most tokens overflow and are dropped
    (output ~ 0 for them) — the output norm must shrink."""
    p, x, (d, f, e, t) = _setup(seed=1)
    y_full, _ = moe.moe_apply(p, x, top_k=2, n_experts=e,
                              capacity_factor=64.0, rt=RT)
    y_tight, _ = moe.moe_apply(p, x, top_k=2, n_experts=e,
                               capacity_factor=0.1, rt=RT)
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))


def test_moe_shared_expert_added():
    key = jax.random.PRNGKey(2)
    d, f, e = 16, 32, 4
    p = moe.moe_init(key, d, f, e, n_shared=1)
    assert "shared" in p
    x = jax.random.normal(jax.random.fold_in(key, 3), (1, 8, d))
    y, _ = moe.moe_apply(p, x, top_k=2, n_experts=e, capacity_factor=64.0,
                         rt=RT)
    # removing the shared expert changes the output
    p2 = dict(p)
    p2.pop("shared")
    y2, _ = moe.moe_apply(p2, x, top_k=2, n_experts=e, capacity_factor=64.0,
                          rt=RT)
    assert float(jnp.linalg.norm(y - y2)) > 1e-3


def test_expert_capacity_formula():
    assert moe.expert_capacity(1024, 8, 2, 1.0) >= 256
    assert moe.expert_capacity(1024, 8, 2, 1.25) >= 320
    assert moe.expert_capacity(10, 64, 8, 1.25) >= 8  # floor


def test_moe_aux_loss_balanced_router_lower():
    """A router that spreads uniformly must have lower aux loss than one
    that collapses to a single expert."""
    p, x, (d, f, e, t) = _setup(seed=3)
    # collapsed router: huge bias toward expert 0 via weight column
    p_bad = jax.tree_util.tree_map(lambda a: a, p)
    w = np.zeros((d, e), np.float32)
    w[:, 0] = 10.0
    p_bad["router"] = {"w": jnp.asarray(w)}
    _, aux_ok = moe.moe_apply(p, x, top_k=2, n_experts=e,
                              capacity_factor=64.0, rt=RT)
    _, aux_bad = moe.moe_apply(p_bad, x, top_k=2, n_experts=e,
                               capacity_factor=64.0, rt=RT)
    assert float(aux_bad) > float(aux_ok)
