"""Scheme-parameterized KV-cache quantization (docs/QUANTIZATION.md):

* paged SPx-quantized serving produces greedy outputs matching the dense
  f32 engine on mixed-length batches (the tentpole acceptance),
* dense ``kv_quant`` decode logits stay within tolerance of the f32 cache
  (including the GQA ``jnp.repeat`` scale-folding path),
* the fused-dequant paged-attention kernel (interpret mode) matches the
  jnp oracle bit-for-bit per scheme,
* pool/cache byte accounting equals the bytes actually allocated,
* SPx level-set edge cases (midpoint ties, codebook padding, sp2_8 uint8
  round-trip), pack_int4 odd-dim errors, PagePool.release errors.

No hypothesis dependency — collected on the bare tier-1 environment.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import spx
from repro.core.quantized import quantize_weight
from repro.kernels import ops
from repro.models import lm as lm_mod
from repro.nn.attention import dequantize_kv, quantize_kv
from repro.runtime import Runtime, planner
from repro.serving.engine import Request, ServeConfig, ServeEngine
from repro.serving.kv_cache import PagePool, kv_bytes_per_token, pool_bytes

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=16)


def _gqa_cfg():
    """Reduced granite: 4 query heads on 1 KV head -> rep=4, exercising
    the GQA repeat of codes AND scales in the decode score/value folds."""
    return reduced(get_config("granite-3-8b"))


def _serving_cfg():
    # vocab=32 keeps random-init top-2 logit gaps wide relative to the
    # ~2% SPx KV error (512-way random logits are mostly near-ties, which
    # would turn the greedy-equality assertion into a coin flip); dh=128
    # is a serving-realistic head width (see benchmarks/serving_bench.py).
    return dataclasses.replace(reduced(get_config("gemma-2b"), vocab=32),
                               head_dim=128)


# ---------------------------------------------------------------------------
# Tentpole acceptance: paged quantized serving == dense f32 greedy outputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["uniform8", "spx_8_x3"])
def test_paged_quant_engine_matches_dense_f32(scheme):
    cfg = _serving_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 9, 17, 6, 12)]

    def drive(layout, rt=RT, **kw):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=2, max_seq=32, quantize=None,
                                      kv_layout=layout, **kw),
                          rt=rt)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        return {r.rid: r.output for r in eng.run()}, eng

    dense, _ = drive("dense")
    quant, eng = drive("paged",
                       rt=RT.replace(kv_quant=True, kv_scheme=scheme),
                       prefill_chunk=8, page_size=16)
    assert eng.kv_layout == "paged" and eng.kv_scheme == scheme
    assert dense == quant, f"greedy divergence under {scheme} KV"
    m = eng.metrics()
    assert m["kv_scheme"] == scheme
    # quantized pages bill codes+scale bytes, not cache-dtype elements
    assert m["peak_kv_bytes"] > 0
    assert (m["peak_kv_bytes"]
            == eng.pool.stats.peak_pages_in_use * eng.page_size
            * kv_bytes_per_token(cfg, kv_scheme=scheme))


def test_paged_quant_undercuts_bf16_pool_bytes():
    """The acceptance's memory axis at matched page geometry: an SPx page
    is codes+scale (dh + 4 bytes/token/head/side) vs bf16's 2*dh."""
    cfg = _serving_cfg()
    spx_tok = kv_bytes_per_token(cfg, kv_scheme="spx_8_x3")
    bf16_tok = kv_bytes_per_token(cfg, jnp.bfloat16)
    assert bf16_tok / spx_tok == pytest.approx(2 * 128 / (128 + 4))
    assert bf16_tok / spx_tok > 1.9


# ---------------------------------------------------------------------------
# Satellite: dense kv_quant routes through the same scheme path (regression
# pinning decode logits against the f32 cache, GQA rep=4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme,atol",
                         [("uniform8", 0.1), ("spx_8_x3", 0.35),
                          ("sp2_8", 0.6)])
def test_dense_kv_quant_decode_close_to_f32(scheme, atol):
    cfg = _gqa_cfg()
    assert cfg.n_heads // cfg.n_kv_heads > 1     # GQA repeat path
    rtq = RT.replace(kv_quant=True, kv_scheme=scheme)
    params = lm_mod.lm_init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, 9),
                       jnp.int32)[None, :]

    caches = lm_mod.init_caches(cfg, 1, 32, dtype=jnp.float32)
    fl, caches = lm_mod.lm_prefill(params, toks, caches, cfg, RT)
    qcaches = lm_mod.init_caches(cfg, 1, 32, dtype=jnp.float32,
                                 kv_quant=True)
    ql, qcaches = lm_mod.lm_prefill(params, toks, qcaches, cfg, rtq)
    # prefill attention runs on the pre-quantization K/V; only the cache
    # write is quantized, so prefill logits are identical
    np.testing.assert_allclose(np.asarray(fl), np.asarray(ql), atol=1e-5)

    pos, tok = 9, int(jnp.argmax(fl[0]))
    for _ in range(6):
        fl, caches = lm_mod.lm_decode_step(
            params, jnp.asarray([tok], jnp.int32), jnp.int32(pos),
            caches, cfg, RT)
        ql, qcaches = lm_mod.lm_decode_step(
            params, jnp.asarray([tok], jnp.int32), jnp.int32(pos),
            qcaches, cfg, rtq)
        np.testing.assert_allclose(np.asarray(fl), np.asarray(ql),
                                   atol=atol)
        assert int(jnp.argmax(fl[0])) == int(jnp.argmax(ql[0]))
        tok = int(jnp.argmax(fl[0]))
        pos += 1


def test_quantize_kv_uniform8_matches_legacy_int8():
    """uniform8 through the codebook path reproduces the old hand-rolled
    symmetric-int8 quantization (same 255 levels, same minmax scale)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 3, 32)), jnp.float32)
    codes, scale = quantize_kv(x, "uniform8")
    assert codes.dtype == jnp.uint8
    xh = dequantize_kv(codes, scale, "uniform8")
    legacy = (jnp.clip(jnp.round(x / scale * 127.0), -127, 127)
              .astype(jnp.int8).astype(jnp.float32) * scale / 127.0)
    np.testing.assert_allclose(np.asarray(xh), np.asarray(legacy),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Kernel parity: fused-dequant paged attention (interpret) vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["uniform8", "sp2_8", "spx_8_x3"])
def test_paged_quant_kernel_interpret_matches_ref(scheme):
    rng = np.random.default_rng(0)
    b, hq, hkv, dh, ps, n_pages, mp = 3, 4, 2, 16, 8, 6, 2
    q = jnp.asarray(rng.standard_normal((b, hq, dh)), jnp.float32)
    kv = rng.standard_normal((2, n_pages, hkv, ps, dh)).astype(np.float32)
    kc, ks = quantize_kv(jnp.asarray(kv[0]), scheme)
    vc, vs = quantize_kv(jnp.asarray(kv[1]), scheme)
    kp = {"codes": kc, "scale": ks}
    vp = {"codes": vc, "scale": vs}
    bt = jnp.asarray(rng.integers(0, n_pages, (b, mp)), jnp.int32)
    ctx = jnp.asarray([0, 5, 13], jnp.int32)     # inactive + partial pages
    ref = ops.paged_attention_quant(q, kp, vp, bt, ctx, kv_scheme=scheme,
                                    impl="ref")
    itp = ops.paged_attention_quant(q, kp, vp, bt, ctx, kv_scheme=scheme,
                                    impl="interpret")
    np.testing.assert_allclose(np.asarray(itp), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert np.all(np.asarray(ref[0]) == 0.0)     # ctx=0 row forced to zero


def test_plan_kv_pages_quant_geometry():
    """Quantized pages are sized for codes+scale bytes and floored at the
    uint8 sublane tile (32 tokens)."""
    planner.clear_plan_cache()
    qplan = planner.plan_kv_pages(1, 128, rep=8, kv_scheme="spx_8_x3")
    fplan = planner.plan_kv_pages(1, 128, rep=8, act_bytes=4)
    assert qplan.page_size >= 32
    assert fplan.page_size >= 8


# ---------------------------------------------------------------------------
# Satellite: byte accounting equals the arrays actually allocated
# ---------------------------------------------------------------------------

def _tree_nbytes(tree):
    return sum(l.nbytes for l in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("dtype,kv_quant",
                         [(jnp.float32, False), (jnp.bfloat16, False),
                          (jnp.float32, True)])
def test_pool_bytes_matches_allocated_nbytes(dtype, kv_quant):
    cfg = _gqa_cfg()
    n_pages, ps = 6, 8
    caches = lm_mod.paged_init_caches(cfg, n_pages, ps, dtype=dtype,
                                      kv_quant=kv_quant)
    scheme = "spx_8_x3" if kv_quant else None
    assert _tree_nbytes(caches) == pool_bytes(cfg, n_pages, ps, dtype,
                                              kv_scheme=scheme)


@pytest.mark.parametrize("dtype,kv_quant",
                         [(jnp.float32, False), (jnp.bfloat16, False),
                          (jnp.float32, True)])
def test_dense_cache_bytes_match_kv_bytes_per_token(dtype, kv_quant):
    cfg = _gqa_cfg()
    b, s = 3, 16
    caches = lm_mod.init_caches(cfg, b, s, dtype=dtype, kv_quant=kv_quant)
    scheme = "uniform8" if kv_quant else None
    assert _tree_nbytes(caches) == b * s * kv_bytes_per_token(
        cfg, dtype, kv_scheme=scheme)


# ---------------------------------------------------------------------------
# Satellite: pack_int4 / quantize_weight on an odd last dim
# ---------------------------------------------------------------------------

def test_pack_int4_odd_last_dim_raises():
    codes = jnp.zeros((4, 7), jnp.uint8)
    with pytest.raises(ValueError, match="even last dim"):
        spx.pack_int4(codes)
    # explicit pack=True on an odd-width weight: clear error, not a
    # broadcast shape crash
    w = jnp.asarray(np.random.default_rng(0).standard_normal((8, 7)),
                    jnp.float32)
    with pytest.raises(ValueError, match="even last dim"):
        quantize_weight(w, "sp2_4", pack=True)
    # auto-pack declines odd dims and still round-trips
    qt = quantize_weight(w, "sp2_4")
    assert not qt.packed and qt.codes.shape == (8, 7)
    assert qt.dequantize().shape == (8, 7)
    # even dims auto-pack as before
    qt2 = quantize_weight(jnp.asarray(
        np.random.default_rng(1).standard_normal((8, 6)), jnp.float32),
        "sp2_4")
    assert qt2.packed and qt2.codes.shape == (8, 3)


# ---------------------------------------------------------------------------
# Satellite: PagePool.release error semantics + stats consistency
# ---------------------------------------------------------------------------

def test_page_pool_release_errors_and_stats_consistent():
    pool = PagePool(n_pages=4, page_size=8)
    assert pool.allocate(7, 20) is not None          # 3 pages
    # release of a never-admitted sequence: descriptive error, no stats
    # drift
    with pytest.raises(KeyError, match="never admitted"):
        pool.release(99)
    assert pool.stats.pages_in_use == 3
    assert pool.stats.release_calls == 0
    # normal release, then double release
    assert pool.release(7) == 3
    assert pool.stats.pages_in_use == 0
    assert pool.stats.release_calls == 1
    with pytest.raises(KeyError, match="double release"):
        pool.release(7)
    assert pool.stats.pages_in_use == 0
    assert pool.stats.release_calls == 1
    assert pool.free_pages() == 4
    # the pool still works after the error paths
    assert pool.allocate(8, 32) is not None
    assert pool.free_pages() == 0


# ---------------------------------------------------------------------------
# Satellite: SPx level-set edge cases
# ---------------------------------------------------------------------------

def test_quantize_to_codes_midpoint_tie_rounds_down():
    """A value exactly on the midpoint of two adjacent levels takes the
    LOWER level (searchsorted side='left' over midpoints) — pinned so a
    refactor to a different tie rule is a visible change."""
    levels = spx.scheme_levels("sp2_4")
    mids = (levels[1:] + levels[:-1]) / 2.0
    codes = spx.quantize_to_codes(jnp.asarray(mids, jnp.float32), levels,
                                  jnp.asarray(1.0))
    np.testing.assert_array_equal(np.asarray(codes),
                                  np.arange(len(levels) - 1))


@pytest.mark.parametrize("scheme", sorted(spx.SCHEMES))
def test_codebook_padding_never_emitted(scheme):
    """The pow2 codebook padding (repeats of the top level) must be
    unreachable from quantize: even +/-inf-magnitude inputs clip to the
    real level range."""
    levels = spx.scheme_levels(scheme)
    lut = spx.codebook(levels)
    x = jnp.asarray([-1e9, -1.0, 0.0, 1.0, 1e9], jnp.float32)
    codes = np.asarray(spx.quantize_to_codes(x, levels, jnp.asarray(1.0)))
    assert codes.max() == len(levels) - 1
    assert codes.max() < lut.shape[0] or len(levels) == lut.shape[0]
    # padding entries all repeat the top level
    np.testing.assert_array_equal(np.asarray(lut[len(levels):]),
                                  np.full(lut.shape[0] - len(levels),
                                          levels[-1], np.float32))


def test_sp2_8_roundtrips_through_uint8_codes():
    """179 levels fit uint8 with headroom: every exact level round-trips
    code -> value with no wraparound and no padding aliasing."""
    levels = spx.scheme_levels("sp2_8")
    assert len(levels) == 179
    vals = jnp.asarray(levels, jnp.float32)
    codes = spx.quantize_to_codes(vals, levels, jnp.asarray(1.0))
    assert codes.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(codes), np.arange(179))
    back = spx.dequantize_codes(codes, spx.codebook(levels),
                                jnp.asarray(1.0), dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(back), levels, atol=1e-7)
