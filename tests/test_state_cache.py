"""Unified StateCache: slab/cross region allocator invariants, and the
acceptance matrix for the four newly pageable architectures — SSM
(xlstm-350m), hybrid (jamba-1.5-large-398b), enc-dec (whisper-small) and
M-RoPE (qwen2-vl-2b) each serve with kv_layout='paged' + scheduler='cb'
producing greedy outputs identical to the dense baseline, at strictly
lower peak state bytes where the paper's memory argument applies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving.engine import Request, ServeConfig, ServeEngine
from repro.serving.kv_cache import (StateCache, cross_kv_bytes_per_seq,
                                    kv_bytes_per_token,
                                    ssm_state_bytes_per_seq)

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=16)


# ---------------------------------------------------------------------------
# Allocator invariants (no engine, no device arrays)
# ---------------------------------------------------------------------------

def test_pageless_pool_slab_exhaustion_and_denial():
    """A pure-SSM pool has zero pages: allocate returns [] (success — NOT
    None) while a free slab exists, denies when slabs run out, and slab
    release restores admission."""
    pool = StateCache(0, 1, n_slabs=2)
    assert pool.allocate(0, 0, need_slab=True) == []
    assert pool.allocate(1, 0, need_slab=True) == []
    pool.validate()
    assert pool.free_slabs() == 0
    assert pool.allocate(2, 0, need_slab=True) is None
    assert pool.stats.admission_denials == 1
    assert pool.stats.peak_slabs_in_use == 2
    pool.release(0)
    pool.validate()
    assert pool.allocate(2, 0, need_slab=True) == []
    assert {pool.seq_slab(1), pool.seq_slab(2)} == {0, 1}
    pool.validate()


def test_cross_entry_shared_revived_and_evicted():
    """Same key -> one entry (refcounted); release keeps it cached-free
    and a later hit revives it; distinct keys past capacity evict the
    coldest zero-ref entry."""
    pool = StateCache(0, 1, n_slabs=4, n_cross=2)
    assert pool.allocate(0, 0, cross_key=b"A") == []
    assert pool.consume_cross_fresh(0)          # miss: caller must encode
    assert not pool.consume_cross_fresh(0)      # exactly once
    assert pool.allocate(1, 0, cross_key=b"A") == []
    assert not pool.consume_cross_fresh(1)      # hit: entry already filled
    assert pool.seq_cross(0) == pool.seq_cross(1)
    assert pool.stats.cross_hits == 1
    pool.release(0)
    pool.release(1)
    pool.validate()
    # cached-free: a new request with the same key revives the entry
    assert pool.allocate(2, 0, cross_key=b"A") == []
    assert not pool.consume_cross_fresh(2)
    assert pool.stats.cross_hits == 2
    # two distinct new keys: the second evicts the zero-ref A entry
    assert pool.allocate(3, 0, cross_key=b"B") == []
    pool.release(2)
    assert pool.allocate(4, 0, cross_key=b"C") == []
    assert pool.consume_cross_fresh(4)
    assert pool.stats.cross_evictions >= 1
    pool.validate()


def test_slab_freed_on_offload_reacquired_on_onload():
    """Offload returns the slab to the free list (its bytes travel in the
    engine payload); onload reacquires one — possibly a different index —
    and the cross reference survives parking."""
    pool = StateCache(4, 8, n_slabs=1, n_cross=1, host_pages=8)
    assert pool.allocate(0, 16, need_slab=True, cross_key=b"A") is not None
    slab0 = pool.seq_slab(0)
    cross0 = pool.seq_cross(0)
    assert pool.offload(0, 1, payload=(object(), object())) is not None
    assert pool.seq_slab(0) is None
    assert pool.free_slabs() == 1
    assert pool.seq_cross(0) == cross0          # kept across parking
    pool.validate()
    pages, payload = pool.onload(0, 16)
    assert pool.seq_slab(0) == slab0            # only slab existed
    assert pool.seq_cross(0) == cross0
    pool.validate()
    pool.release(0)
    pool.validate()


def test_all_or_nothing_admission_across_regions():
    """A request needing pages AND a slab is denied whole when either
    region is short — no partial reservations left behind."""
    pool = StateCache(2, 8, n_slabs=1)
    assert pool.allocate(0, 16, need_slab=True) is not None
    # pages exhausted, slab exhausted: deny, and state is untouched
    assert pool.allocate(1, 8, need_slab=True) is None
    assert pool.free_pages() == 0 and pool.free_slabs() == 0
    pool.validate()
    pool.release(0)
    assert pool.free_pages() == 2 and pool.free_slabs() == 1
    pool.validate()


def test_state_byte_helpers_cover_regions():
    xl = reduced(get_config("xlstm-350m"), n_layers=4)
    wh = reduced(get_config("whisper-small"))
    gr = reduced(get_config("granite-3-8b"))
    assert kv_bytes_per_token(xl, jnp.float32) == 0          # no attn KV
    assert ssm_state_bytes_per_seq(xl, jnp.float32) > 0
    assert ssm_state_bytes_per_seq(gr, jnp.float32) == 0
    assert cross_kv_bytes_per_seq(
        encdec_mod.dec_cfg(wh), jnp.float32) > 0
    assert cross_kv_bytes_per_seq(gr, jnp.float32) == 0


# ---------------------------------------------------------------------------
# Architecture matrix: paged + cb == dense greedy, per arch
# ---------------------------------------------------------------------------

def _build(arch):
    """(cfg, params, frames list or None) at smoke scale."""
    if arch == "whisper-small":
        cfg = reduced(get_config("whisper-small"))
        params = encdec_mod.encdec_init(jax.random.PRNGKey(2), cfg)
        fr = np.asarray(jax.random.normal(
            jax.random.PRNGKey(3), (2, cfg.enc_seq_len, cfg.d_model)))
        frames = [fr[0], fr[0], fr[1]]       # rid 0 and 1 share an input
        return cfg, params, frames
    n_layers = {"xlstm-350m": 4, "jamba-1.5-large-398b": 8,
                "qwen2-vl-2b": 2}[arch]
    cfg = reduced(get_config(arch), n_layers=n_layers)
    params = lm_mod.lm_init(jax.random.PRNGKey(1), cfg)
    return cfg, params, None


def _serve(cfg, params, layout, scheduler, prompts, frames,
           batch_slots=4, inject_preempt=False):
    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=batch_slots, max_seq=64,
                                  quantize=None, kv_layout=layout,
                                  scheduler=scheduler),
                      rt=RT)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=np.asarray(p, np.int32),
                           max_new_tokens=8,
                           frames=None if frames is None else frames[i]))
    if inject_preempt:
        for _ in range(5):
            eng.step()
        for r in eng.slot_req:
            if r is not None:
                eng.preempt(r.rid)
                break
    eng.run(max_steps=4000)
    return {r.rid: list(r.output) for r in eng.finished}, eng.metrics()


_ARCHS = ["xlstm-350m", "jamba-1.5-large-398b", "whisper-small",
          "qwen2-vl-2b"]


@pytest.mark.parametrize("arch", _ARCHS)
def test_arch_serves_paged_cb_identical_to_dense(arch):
    """Acceptance (per ISSUE): each architecture serves with
    kv_layout='paged', scheduler='cb' and greedy outputs are identical to
    the dense baseline; SSM and enc-dec record strictly lower peak state
    bytes (fewer live sequences than dense's always-billed slots)."""
    cfg, params, frames = _build(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (7, 19, 12)]
    dense, md = _serve(cfg, params, "dense", "fifo", prompts, frames)
    paged, mp = _serve(cfg, params, "paged", "cb", prompts, frames)
    assert dense == paged
    assert mp["kv_layout"] == "paged" and mp["scheduler"] == "cb"
    if arch != "qwen2-vl-2b":
        # 3 requests in 4 slots: dense bills every slot's worst case,
        # the state cache bills only what was live
        assert mp["peak_state_bytes"] < md["peak_state_bytes"]


@pytest.mark.parametrize("arch", ["xlstm-350m", "whisper-small"])
def test_preempt_resume_keeps_outputs_identical(arch):
    """Slab snapshot/restore (SSM) and the parked-but-kept cross entry
    (enc-dec) round-trip through preemption without changing outputs."""
    cfg, params, frames = _build(arch)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (9, 15, 11)]
    base, _ = _serve(cfg, params, "dense", "fifo", prompts, frames,
                     batch_slots=2)
    pre, m = _serve(cfg, params, "paged", "cb", prompts, frames,
                    batch_slots=2, inject_preempt=True)
    assert base == pre
    assert m["preemptions"] >= 1 and m["resumes"] >= 1
    assert m["offload_bytes"] > 0 and m["onload_bytes"] > 0


def test_encoder_output_shared_across_requests():
    """Two whisper requests with identical frames share one cross entry:
    the encoder runs once for them, and the peak cross occupancy counts
    distinct inputs, not requests."""
    cfg, params, frames = _build("whisper-small")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, 6).tolist()
               for _ in range(3)]
    out, m = _serve(cfg, params, "paged", "cb", prompts, frames)
    assert len(out) == 3
    assert m["cross_lookups"] == 3
    assert m["cross_hits"] == 1                 # rid 1 reused rid 0's pass
    assert m["peak_cross"] == 2                 # two distinct inputs
    assert m["cross_bytes_per_entry"] > 0


def test_unsupported_features_enumerate_failing_predicates():
    """Explicit prefix_cache/spec_decode on patterns that cannot support
    them raise with the actual failing predicate(s) named (satellite of
    the old 'attention-only pattern' catch-all message)."""
    xl, xp, _ = _build("xlstm-350m")
    with pytest.raises(ValueError, match=r"mlstm.*slstm|recurrent"):
        ServeEngine(xp, xl,
                    ServeConfig(quantize=None, kv_layout="paged",
                                prefix_cache=True),
                    rt=RT)
    with pytest.raises(ValueError, match="roll back"):
        ServeEngine(xp, xl,
                    ServeConfig(quantize=None, kv_layout="paged",
                                spec_decode=True),
                    rt=RT)
    wh, wp, _ = _build("whisper-small")
    with pytest.raises(ValueError, match="enc_dec"):
        ServeEngine(wp, wh,
                    ServeConfig(quantize=None, kv_layout="paged",
                                prefix_cache=True),
                    rt=RT)
    # enc-dec requests must carry frames
    eng = ServeEngine(wp, wh, ServeConfig(quantize=None, kv_layout="paged"),
                      rt=RT)
    with pytest.raises(ValueError, match="frames"):
        eng.submit(Request(rid=0, prompt=np.asarray([1, 2], np.int32),
                           max_new_tokens=2))
