"""Docs stay executable: every fenced python snippet in README.md and
docs/*.md compiles, and `# exec-check` blocks run (same checker CI uses —
tools/check_doc_snippets.py)."""
import os
import sys

_TOOLS = os.path.normpath(os.path.join(os.path.dirname(__file__), "..",
                                       "tools"))
sys.path.insert(0, _TOOLS)

import check_doc_snippets  # noqa: E402


def test_doc_snippets_compile_and_exec():
    failures = []
    for f in check_doc_snippets.default_files():
        failures.extend(check_doc_snippets.check_file(f))
    assert not failures, "\n".join(failures)


def test_docs_exist_and_crosslinked():
    readme = open(os.path.join(check_doc_snippets.REPO, "README.md")).read()
    serving = open(os.path.join(check_doc_snippets.REPO, "docs",
                                "SERVING.md")).read()
    design = open(os.path.join(check_doc_snippets.REPO, "DESIGN.md")).read()
    assert "docs/SERVING.md" in readme
    assert "docs/SERVING.md" in design          # cross-link from DESIGN
    assert "DESIGN.md" in serving
    assert "pytest" in readme                   # tier-1 verify command
