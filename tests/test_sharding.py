"""Distribution machinery on a small host-device mesh (the 512-device
production dry-run is launch/dryrun.py; these tests validate the same
code paths in CI scale)."""
import os
import subprocess
import sys

import pytest

# spawn a subprocess with 8 host devices so this file doesn't poison the
# single-device state of the rest of the suite
_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import jax.numpy as jnp

import sys
sys.path.insert(0, "src")
from repro.configs import get_config, reduced
from repro.compat import cost_analysis_dict
from repro.launch.mesh import ambient_mesh, mesh_axis_kwargs
from repro.configs.base import ShapeConfig
from repro.launch.steps import build_step
from repro.sharding import make_policy

def small_mesh():
    return jax.make_mesh((2, 4), ("data", "model"), **mesh_axis_kwargs(2))

def run_cell(arch, kind):
    cfg = reduced(get_config(arch), d_model=64, vocab=512)
    # dims divisible by the 4-wide model axis
    cfg = dataclasses.replace(cfg, n_kv_heads=cfg.n_kv_heads)
    shape = ShapeConfig("t", seq_len=64, global_batch=8, kind=kind)
    mesh = small_mesh()
    with ambient_mesh(mesh):
        bundle = build_step(cfg, shape, mesh)
        jfn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings,
                      donate_argnums=bundle.donate_argnums)
        compiled = jfn.lower(*bundle.args).compile()
    assert cost_analysis_dict(compiled).get("flops", 0) > 0
    print(f"OK {arch} {kind}")

arch, kind = sys.argv[1], sys.argv[2]
run_cell(arch, kind)
"""


def _run(arch: str, kind: str):
    r = subprocess.run(
        [sys.executable, "-c", _WORKER, arch, kind],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, f"{arch}/{kind}:\n{r.stdout}\n{r.stderr[-3000:]}"
    assert f"OK {arch} {kind}" in r.stdout


@pytest.mark.parametrize("arch", ["granite-3-8b", "olmoe-1b-7b",
                                  "jamba-1.5-large-398b", "xlstm-350m",
                                  "whisper-small", "qwen2-vl-2b"])
def test_train_step_compiles_sharded(arch):
    _run(arch, "train")


@pytest.mark.parametrize("arch", ["granite-3-8b", "olmoe-1b-7b",
                                  "xlstm-350m"])
def test_decode_step_compiles_sharded(arch):
    _run(arch, "decode")


@pytest.mark.parametrize("arch", ["granite-3-8b", "whisper-small"])
def test_prefill_step_compiles_sharded(arch):
    _run(arch, "prefill")


def test_policy_specs_divisible():
    """Every input sharding the policy assigns must divide the dim size
    (jit inputs cannot shard unevenly)."""
    wrk = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import mesh_axis_kwargs
from repro.launch.steps import _params_sds
from repro.sharding import make_policy

mesh = jax.make_mesh((2, 4), ("data", "model"), **mesh_axis_kwargs(2))
sizes = dict(mesh.shape)
for arch in ("granite-3-8b", "kimi-k2-1t-a32b", "jamba-1.5-large-398b"):
    cfg = get_config(arch)
    sds = _params_sds(cfg, jnp.bfloat16, quantized=False)
    policy = make_policy(cfg, mesh)
    specs = policy.param_specs(sds)
    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    flat_x, _ = jax.tree_util.tree_flatten(sds)
    for spec, leaf in zip(flat_s, flat_x):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= sizes[a]
            assert dim % n == 0, (arch, leaf.shape, spec)
print("OK divisible")
"""
    r = subprocess.run([sys.executable, "-c", wrk], capture_output=True,
                       text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK divisible" in r.stdout
