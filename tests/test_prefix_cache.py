"""Shared-prefix KV page reuse: PagePool refcount/prefix-index semantics,
allocation-failure atomicity, seeded randomized pool invariants
(hypothesis-free), engine greedy determinism across scheduling knobs on
the pinned vocab=32/dh=128/seed-3 workload, and metrics() math against
synthetic timestamps."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm as lm_mod
from repro.runtime import Runtime, planner
from repro.serving.engine import Request, ServeConfig, ServeEngine
from repro.serving.kv_cache import PagePool

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=16)


# ---------------------------------------------------------------------------
# PagePool: prefix share / revive / evict lifecycle
# ---------------------------------------------------------------------------

def test_pool_prefix_share_revive_evict():
    pool = PagePool(6, 4)
    t = np.arange(8, dtype=np.int32)                  # two full pages
    pages0 = pool.allocate(0, 10)                     # 3 pages
    pool.register_prefix(0, t)
    assert pool.match_prefix(t) == pages0[:2]
    # positional chain: the same block at a different position must miss
    assert pool.match_prefix(t[4:]) == []
    # share while the owner lives: refcount bump, one page counted once
    t2 = np.concatenate([t, [42]]).astype(np.int32)
    shared = pool.match_prefix(t2)
    pages1 = pool.allocate(1, 11, shared_prefix=shared)
    assert pages1[:2] == shared
    assert pool.ref_count(shared[0]) == 2
    assert pool.stats.pages_in_use == 4               # 3 + 1 fresh
    assert pool.stats.prefix_pages_shared == 2
    # first owner releases: shared pages survive on the second owner
    pool.release(0)
    assert pool.ref_count(shared[0]) == 1
    assert pool.free_pages() == 3
    # last owner releases: pages free but stay indexed (lazy eviction)
    pool.release(1)
    assert pool.free_pages() == 6
    assert pool.match_prefix(t) == shared
    # a new request revives the cached pages out of the free list
    pages2 = pool.allocate(2, 9, shared_prefix=pool.match_prefix(t2))
    assert pages2[:2] == shared
    assert pool.ref_count(shared[0]) == 1
    pool.release(2)
    # fresh allocations that reuse the physical pages evict the cache
    assert pool.allocate(3, 24) is not None           # the whole pool
    assert pool.match_prefix(t) == []
    pool.validate()


def test_register_prefix_requires_live_seq_and_is_idempotent():
    pool = PagePool(4, 4)
    with pytest.raises(KeyError, match="not live"):
        pool.register_prefix(9, np.arange(4, dtype=np.int32))
    t = np.arange(8, dtype=np.int32)
    pool.allocate(0, 8)
    pool.register_prefix(0, t)
    before = pool.cached_prefix_pages()
    pool.register_prefix(0, t)                        # no-op, no dup entries
    assert pool.cached_prefix_pages() == before == 2
    # partial feed registers only the full pages covered so far
    pool.allocate(1, 8)
    pool.register_prefix(1, np.arange(100, 108, dtype=np.int32), 5)
    assert pool.match_prefix(np.arange(100, 108, dtype=np.int32)) \
        == [pool.seq_pages(1)[0]]
    pool.validate()


# ---------------------------------------------------------------------------
# Satellite: allocate() atomicity on every failure path
# ---------------------------------------------------------------------------

def _snapshot(pool):
    return (list(pool._free), list(pool._ref),
            {k: list(v) for k, v in pool._seq_pages.items()},
            dict(pool._index), dict(pool._page_key),
            dataclasses.replace(pool.stats))


def test_allocate_failure_leaves_pool_state_untouched():
    """A failing allocate — any raised caller error — must leave the free
    list, refcounts, sequence map, prefix index and stats exactly as they
    were: no leaked or half-reserved pages."""
    pool = PagePool(6, 4)
    t = np.arange(8, dtype=np.int32)
    pool.allocate(0, 10)
    pool.register_prefix(0, t)
    shared = pool.match_prefix(t)
    snap = _snapshot(pool)

    # duplicate seq id
    with pytest.raises(KeyError, match="already allocated"):
        pool.allocate(0, 4)
    assert _snapshot(pool) == snap
    # shared page that is neither live nor indexed (stale match)
    with pytest.raises(ValueError, match="not.*shareable|neither"):
        pool.allocate(1, 12, shared_prefix=[5])
    assert _snapshot(pool) == snap
    # out-of-range and duplicated shared pages
    with pytest.raises(ValueError, match="out of range or duplicated"):
        pool.allocate(1, 12, shared_prefix=[99])
    assert _snapshot(pool) == snap
    with pytest.raises(ValueError, match="out of range or duplicated"):
        pool.allocate(1, 12, shared_prefix=[shared[0], shared[0]])
    assert _snapshot(pool) == snap
    # more shared pages than the reservation needs
    with pytest.raises(ValueError, match="only need"):
        pool.allocate(1, 4, shared_prefix=shared)
    assert _snapshot(pool) == snap
    # capacity denial: returns None, moves ONLY the denial counters
    assert pool.allocate(1, 100) is None
    free, ref, seqs, index, inverse, stats = _snapshot(pool)
    assert (free, ref, seqs, index, inverse) == snap[:5]
    assert stats.pages_in_use == snap[5].pages_in_use
    assert stats.alloc_calls == snap[5].alloc_calls + 1
    assert stats.admission_denials == snap[5].admission_denials + 1
    # the pool still works after every error path
    assert pool.allocate(1, 12, shared_prefix=shared) is not None
    pool.validate()


# ---------------------------------------------------------------------------
# Satellite: seeded randomized pool invariants (hypothesis-free)
# ---------------------------------------------------------------------------

def test_pool_invariants_randomized():
    """Across interleaved allocate/share/release/register sequences:
    free+held page conservation, refcount == number of owning sequences
    (no page in two sequences unless its refcount says so), free-list
    exactness, index consistency, and PoolStats occupancy bounds / peak
    monotonicity. Seeded — failures reproduce."""
    rng = np.random.default_rng(0)
    for n_pages, ps in ((8, 4), (16, 8), (5, 16)):
        pool = PagePool(n_pages, ps)
        live: dict[int, np.ndarray] = {}
        registered: list[np.ndarray] = []
        next_id = 0
        peak_prev = 0
        for _ in range(300):
            op = rng.random()
            if op < 0.5:
                if registered and rng.random() < 0.5:
                    base = registered[int(rng.integers(len(registered)))]
                    tail = rng.integers(0, 100, int(rng.integers(0, 2 * ps)))
                    tokens = np.concatenate([base, tail]).astype(np.int32)
                else:
                    tokens = rng.integers(
                        0, 100, int(rng.integers(1, 4 * ps))).astype(np.int32)
                n_total = len(tokens) + int(rng.integers(1, ps))
                shared = pool.match_prefix(tokens)
                shared = shared[:pool.pages_for(n_total)]
                if len(shared) * ps >= len(tokens):
                    shared = shared[:-1]        # engine's COW cap
                if pool.allocate(next_id, n_total,
                                 shared_prefix=shared) is not None:
                    live[next_id] = tokens
                next_id += 1
            elif op < 0.75 and live:
                sid = int(rng.choice(list(live)))
                pool.register_prefix(sid, live[sid])
                registered.append(live[sid])
            elif live:
                sid = int(rng.choice(list(live)))
                pool.release(sid)
                del live[sid]
            pool.validate()
            # cross-check through the public API too
            owners: dict[int, int] = {}
            for sid in live:
                for p in pool.seq_pages(sid):
                    owners[p] = owners.get(p, 0) + 1
            for p in range(n_pages):
                assert pool.ref_count(p) == owners.get(p, 0)
            assert pool.free_pages() + pool.stats.pages_in_use == n_pages
            assert 0.0 <= pool.stats.occupancy <= 1.0
            assert pool.stats.peak_pages_in_use >= peak_prev
            peak_prev = pool.stats.peak_pages_in_use
        assert pool.stats.alloc_calls > 0 and pool.stats.release_calls > 0
        assert pool.stats.prefix_pages_shared > 0, \
            "randomized driver never exercised sharing"


def test_pool_two_tier_invariants_randomized():
    """Extend the seeded invariant storm to the two-tier (device + host)
    pool: interleaved allocate/offload/onload/release sequences must keep
    byte payloads conserved across tiers (onload returns exactly the
    bytes offload parked), host occupancy == the sum of parked entries,
    the free list exact after every onload, no double offload, and
    validate() green after every op. Seeded — failures reproduce."""
    rng = np.random.default_rng(1)
    for n_pages, ps, host in ((8, 4, None), (16, 8, 6), (6, 4, 3)):
        pool = PagePool(n_pages, ps, host_pages=host)
        live: dict[int, int] = {}           # sid -> n_total tokens
        parked: dict[int, tuple] = {}       # sid -> (n_total, n_keep, bytes)
        next_id = 0
        for _ in range(400):
            op = rng.random()
            if op < 0.35:
                n_total = int(rng.integers(1, 3 * ps))
                if pool.allocate(next_id, n_total) is not None:
                    live[next_id] = n_total
                next_id += 1
            elif op < 0.55 and live:
                sid = int(rng.choice(list(live)))
                n_keep = int(rng.integers(0, pool.seq_page_count(sid) + 1))
                payload = rng.integers(0, 256, 16).astype(np.uint8)
                free_before = pool.free_pages()
                releasable = pool.releasable_pages(sid)
                if pool.offload(sid, n_keep, payload.copy()) is None:
                    # denial only ever means the host bound, and it is
                    # side-effect free
                    assert host is not None
                    assert pool.stats.host_pages_in_use + n_keep > host
                    assert pool.free_pages() == free_before
                else:
                    assert pool.free_pages() == free_before + releasable
                    parked[sid] = (live.pop(sid), n_keep, payload)
                    with pytest.raises(KeyError, match="offload"):
                        pool.offload(sid, 0)        # no double offload
            elif op < 0.75 and parked:
                sid = int(rng.choice(list(parked)))
                n_total, n_keep, payload = parked[sid]
                res = pool.onload(sid, n_total)
                if res is not None:
                    pages, got = res
                    assert np.array_equal(got, payload), \
                        "payload bytes not conserved across tiers"
                    assert len(pages) == pool.pages_for(n_total)
                    live[sid] = n_total
                    del parked[sid]
            elif live:
                sid = int(rng.choice(list(live)))
                pool.release(sid)
                del live[sid]
            pool.validate()
            assert pool.stats.host_pages_in_use == \
                sum(k for _, k, _ in parked.values())
            assert pool.free_pages() + pool.stats.pages_in_use == n_pages
        assert pool.stats.offload_calls > 0 and pool.stats.onload_calls > 0


def test_offload_onload_errors_and_free_list_exactness():
    pool = PagePool(6, 4, host_pages=2)
    with pytest.raises(KeyError, match="not live"):
        pool.offload(0, 1)
    with pytest.raises(KeyError, match="not offloaded"):
        pool.onload(0, 8)
    pages0 = pool.allocate(0, 8)                    # 2 pages
    with pytest.raises(ValueError, match="n_host_pages"):
        pool.offload(0, 3)                          # owns only 2
    assert pool.offload(0, 2, "blob") == 2
    assert pool.host_resident(0) and pool.host_payload_pages(0) == 2
    assert pool.free_pages() == 6
    with pytest.raises(KeyError, match="double offload"):
        pool.offload(0, 1)
    # host tier full: denial, victim stays live
    pool.allocate(1, 8)
    assert pool.offload(1, 2) is None
    assert pool.seq_pages(1) and not pool.host_resident(1)
    # onload restores the payload and the free list exactly
    pages, payload = pool.onload(0, 8)
    assert payload == "blob" and len(pages) == 2
    assert pool.free_pages() == 6 - 2 - 2
    assert not pool.host_resident(0)
    with pytest.raises(KeyError, match="not offloaded"):
        pool.onload(0, 8)
    pool.validate()
    # shared pages survive a co-owner's offload (ref-aware release)
    t = np.arange(4, dtype=np.int32)
    pool.register_prefix(0, t)
    shared = pool.match_prefix(np.concatenate([t, [9]]).astype(np.int32))
    pool.allocate(2, 5, shared_prefix=shared)
    assert pool.ref_count(shared[0]) == 2
    assert pool.offload(0, 2) == 1                  # shared page stays
    assert pool.ref_count(shared[0]) == 1
    pool.validate()
    del pages0


def test_prefix_cache_capacity_lru_eviction():
    """cache_pages bounds the cached-free index: past it, the
    least-recently-touched entry is evicted (and counted); pages pinned
    by live owners never count against the bound."""
    pool = PagePool(8, 4, cache_pages=2)
    prompts = [np.arange(10 * i, 10 * i + 4, dtype=np.int32)
               for i in range(3)]
    for sid, t in enumerate(prompts):
        pool.allocate(sid, 4)
        pool.register_prefix(sid, t)
    # three live indexed pages: fine, the bound counts cached-FREE only
    assert pool.cached_prefix_pages() == 3
    pool.validate()
    pool.release(0)
    pool.release(1)
    assert pool.stats.prefix_evictions == 0
    pool.release(2)                     # third cached-free page: evict LRU
    assert pool.stats.prefix_evictions == 1
    assert pool.match_prefix(prompts[0]) == []      # oldest touch evicted
    assert len(pool.match_prefix(prompts[1])) == 1
    assert len(pool.match_prefix(prompts[2])) == 1
    pool.validate()
    # the matches above touched prompts[1] then prompts[2]: registering a
    # third cached-free entry evicts prompts[1], the oldest touch
    pool.allocate(3, 4)
    pool.register_prefix(3, prompts[0])
    pool.release(3)
    assert pool.stats.prefix_evictions == 2
    assert pool.match_prefix(prompts[1]) == []      # oldest touch evicted
    assert len(pool.match_prefix(prompts[2])) == 1
    pool.validate()


def test_fresh_allocations_prefer_unindexed_pages():
    """A cached prefix must be the LAST thing a fresh allocation
    recycles: free un-indexed pages go first."""
    pool = PagePool(4, 4)
    t = np.arange(4, dtype=np.int32)
    pool.allocate(0, 4)
    pool.register_prefix(0, t)
    cached = pool.seq_pages(0)[0]
    pool.release(0)                     # cached-free now
    pages = pool.allocate(1, 12)        # 3 of 4 pages fresh
    assert cached not in pages, "fresh alloc recycled the cached prefix"
    assert len(pool.match_prefix(t)) == 1
    # only when every free page is indexed does the LRU one recycle
    pages2 = pool.allocate(2, 4)
    assert pages2 == [cached]
    assert pool.match_prefix(t) == []   # evicted with the reuse
    assert pool.stats.prefix_evictions >= 1
    pool.validate()


def test_prefix_lookup_hit_counters():
    pool = PagePool(4, 4)
    t = np.arange(8, dtype=np.int32)
    pool.allocate(0, 8)
    assert pool.match_prefix(t) == []               # miss
    pool.register_prefix(0, t)
    assert len(pool.match_prefix(t)) == 2           # hit
    assert pool.stats.prefix_lookups == 2
    assert pool.stats.prefix_hits == 1
    pool.validate()


def test_plan_seq_pages_model():
    assert planner.plan_seq_pages(33, 8) == 5
    assert planner.plan_seq_pages(33, 8, shared_tokens=24) == 2
    # COW case: a partially reused last page still bills as fresh
    assert planner.plan_seq_pages(32, 8, shared_tokens=31) == 1
    assert planner.plan_seq_pages(0, 8) == 0
    with pytest.raises(ValueError):
        planner.plan_seq_pages(8, 8, shared_tokens=9)
    with pytest.raises(ValueError):
        planner.plan_seq_pages(8, 0)


# ---------------------------------------------------------------------------
# Satellite: engine greedy determinism on the pinned workload
# ---------------------------------------------------------------------------

# vocab=32 keeps top-2 logit gaps wide relative to the quantization error
# (exact-output asserts at vocab=512 flip on near-ties); dh=128 keeps the
# quantized byte ratios representative — same pinned workload as
# benchmarks/serving_bench.py.
CFG_PIN = dataclasses.replace(reduced(get_config("gemma-2b"), vocab=32),
                              head_dim=128)


def _drive(params, rt, prompts, order, slots, prefix_on):
    eng = ServeEngine(params, CFG_PIN,
                      ServeConfig(batch_slots=slots, max_seq=48,
                                  quantize="sp2_4", kv_layout="paged",
                                  page_size=8, prefix_cache=prefix_on),
                      rt=rt)
    for i in order:
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=3))
    out = {r.rid: r.output for r in eng.run()}
    eng.pool.validate()
    return out


@pytest.mark.parametrize("kvq", [False, True], ids=["f32", "kv_quant"])
def test_engine_greedy_invariant_to_schedule_knobs(kvq):
    """Greedy outputs on the pinned seed-3 workload are a function of
    (params, prompt) only: invariant to request submit order, batch_slots,
    and prefix-cache on/off — for plain paged and paged+kv_quant pools."""
    rt = RT.replace(kv_quant=True, kv_scheme="spx_8_x3") if kvq else RT
    params = lm_mod.lm_init(jax.random.PRNGKey(3), CFG_PIN)
    rng = np.random.default_rng(3)
    sys_p = rng.integers(0, CFG_PIN.vocab_size, 8).astype(np.int32)
    # one bare page-aligned duplicate (index 2) so the COW path is in play
    prompts = [np.concatenate(
        [sys_p, rng.integers(0, CFG_PIN.vocab_size, n).astype(np.int32)])
        for n in (2, 5, 0, 9)]

    base = _drive(params, rt, prompts, [0, 1, 2, 3], 2, False)
    assert _drive(params, rt, prompts, [3, 1, 0, 2], 2, False) == base
    assert _drive(params, rt, prompts, [0, 1, 2, 3], 3, False) == base
    assert _drive(params, rt, prompts, [0, 1, 2, 3], 2, True) == base
    assert _drive(params, rt, prompts, [2, 3, 0, 1], 3, True) == base


# ---------------------------------------------------------------------------
# Satellite: metrics() math on synthetic timestamps
# ---------------------------------------------------------------------------

def _mini_engine(**kw):
    cfg = reduced(get_config("granite-3-8b"))
    params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params, cfg,
                       ServeConfig(batch_slots=2, max_seq=16, quantize=None,
                                   **kw),
                       rt=RT)


def _fake_request(rid, enq, ttft_s, lat_s):
    r = Request(rid=rid, prompt=np.zeros(2, np.int32), max_new_tokens=4)
    r.t_enqueue = enq
    r.t_first_token = enq + ttft_s
    r.t_done = enq + lat_s
    r.done = True
    return r


def test_metrics_math_synthetic_timestamps():
    eng = _mini_engine(kv_layout="dense")
    ttfts = (0.010, 0.020, 0.030, 0.040)
    lats = (0.100, 0.200, 0.300, 0.400)
    eng.finished = [_fake_request(i, 50.0 * i, t, l)
                    for i, (t, l) in enumerate(zip(ttfts, lats))]
    eng._tokens_out = 40
    eng._wall = 2.0
    eng._steps = 7
    m = eng.metrics()
    assert m["tokens_per_s"] == 20.0
    assert m["requests_finished"] == 4 and m["engine_steps"] == 7
    assert m["ttft_p50_ms"] == pytest.approx(25.0)
    # linear-interpolated p95 of [10, 20, 30, 40] ms: 30 + 0.85*10
    assert m["ttft_p95_ms"] == pytest.approx(38.5)
    assert m["latency_p50_ms"] == pytest.approx(250.0)
    assert m["latency_p95_ms"] == pytest.approx(385.0)


def test_metrics_single_sample_p95_equals_the_sample():
    eng = _mini_engine(kv_layout="dense")
    eng.finished = [_fake_request(0, 5.0, 0.007, 0.050)]
    m = eng.metrics()
    assert m["ttft_p50_ms"] == m["ttft_p95_ms"] == pytest.approx(7.0)
    assert m["latency_p50_ms"] == m["latency_p95_ms"] == pytest.approx(50.0)


def test_reset_metrics_clears_counters_and_prefix_stats():
    eng = _mini_engine(kv_layout="paged", page_size=8, prefix_cache=True)
    eng.finished = [_fake_request(0, 1.0, 0.001, 0.002)]
    eng._tokens_out, eng._wall, eng._steps = 10, 1.0, 3
    eng._occ_samples = [0.5]
    eng._prefix_hits, eng._prefill_skipped, eng._cow_copies = 3, 42, 2
    eng.pool.stats.admission_denials = 5
    eng.reset_metrics()
    m = eng.metrics()
    assert m["requests_finished"] == 0 and m["tokens_generated"] == 0
    assert m["wall_s"] == 0.0 and m["tokens_per_s"] == 0.0
    assert m["ttft_p50_ms"] == m["ttft_p95_ms"] == 0.0
    assert m["latency_p50_ms"] == m["latency_p95_ms"] == 0.0
    assert m["occupancy_mean"] == m["occupancy_peak"] == 0.0
    assert m["prefix_hits"] == 0 and m["prefill_tokens_skipped"] == 0
    assert m["cow_copies"] == 0 and m["admission_denials"] == 0
    assert m["prefix_cache"] is True
