"""Attention unit tests: chunked online attention vs naive oracle, RoPE
properties, decode-attention (flash-decode) consistency, GQA grouping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref as kref
from repro.nn import attention as attn
from repro.runtime import Runtime
from repro.nn.rotary import apply_mrope, apply_rope

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=8)


def _mk(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# chunked attention == naive attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q_chunk", [4, 8, 16, 64])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_naive(q_chunk, causal):
    b, h, s, dh = 2, 3, 32, 16
    q, k, v = _mk((b, h, s, dh), 1), _mk((b, h, s, dh), 2), \
        _mk((b, h, s, dh), 3)
    got = attn._chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk)
    want = kref.attention_ref(q.reshape(b * h, s, dh),
                              k.reshape(b * h, s, dh),
                              v.reshape(b * h, s, dh),
                              causal=causal).reshape(b, h, s, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_attention_core_gqa_grouping():
    b, hq, hkv, s, dh = 2, 8, 2, 16, 8
    q = _mk((b, hq, s, dh), 4)
    k = _mk((b, hkv, s, dh), 5)
    v = _mk((b, hkv, s, dh), 6)
    got = attn.attention_core(q, k, v, causal=True, rt=RT)
    kr = jnp.repeat(k, 4, axis=1)
    vr = jnp.repeat(v, 4, axis=1)
    want = kref.attention_ref(q.reshape(-1, s, dh), kr.reshape(-1, s, dh),
                              vr.reshape(-1, s, dh),
                              causal=True).reshape(q.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(shift=st.integers(0, 16), seed=st.integers(0, 100))
def test_rope_relative_position_invariance(shift, seed):
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    dh = 16
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, dh)), jnp.float32)
    def dot_at(i, j):
        qi = apply_rope(q, jnp.full((1, 1), i, jnp.int32))
        kj = apply_rope(k, jnp.full((1, 1), j, jnp.int32))
        return float(jnp.sum(qi * kj))
    a = dot_at(3, 1)
    b = dot_at(3 + shift, 1 + shift)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)


def test_rope_preserves_norm():
    x = _mk((2, 5, 3, 32), 7)
    pos = jnp.broadcast_to(jnp.arange(5, dtype=jnp.int32), (2, 5))
    y = apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


def test_mrope_equals_rope_when_streams_equal():
    """Text-only M-RoPE (t=h=w) must reduce exactly to RoPE."""
    b, s, h, dh = 2, 6, 2, 24
    x = _mk((b, s, h, dh), 8)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pos3 = jnp.broadcast_to(pos[:, None, :], (b, 3, s))
    got = apply_mrope(x, pos3, sections=(4, 4, 4))
    want = apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# flash-decode (local path)
# ---------------------------------------------------------------------------

def test_decode_attention_matches_full_softmax():
    b, hq, hkv, s, dh = 2, 4, 2, 16, 8
    k_cache = _mk((b, hkv, s, dh), 9)
    v_cache = _mk((b, hkv, s, dh), 10)
    q = _mk((b, hq, dh), 11)
    k_new = _mk((b, hkv, dh), 12)
    v_new = _mk((b, hkv, dh), 13)
    pos = jnp.int32(7)
    out, k2, v2 = attn.decode_attention(q, k_cache, v_cache, k_new, v_new,
                                        pos, rt=RT)
    # oracle: cache with position 7 overwritten, attend to <= 7
    kc = k_cache.at[:, :, 7].set(k_new)
    vc = v_cache.at[:, :, 7].set(v_new)
    kr = jnp.repeat(kc, 2, axis=1)
    vr = jnp.repeat(vc, 2, axis=1)
    sc = jnp.einsum("bhd,bhkd->bhk", q, kr) / np.sqrt(dh)
    mask = jnp.arange(s) <= 7
    sc = jnp.where(mask[None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    want = jnp.einsum("bhk,bhkd->bhd", p, vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(kc), rtol=1e-6)


def test_decode_attention_per_slot_positions():
    """Different per-batch positions: each row writes its own slot and
    masks its own depth."""
    b, hkv, s, dh = 2, 1, 8, 4
    k_cache = _mk((b, hkv, s, dh), 14)
    v_cache = _mk((b, hkv, s, dh), 15)
    q = _mk((b, 2, dh), 16)
    k_new = _mk((b, hkv, dh), 17)
    v_new = _mk((b, hkv, dh), 18)
    pos = jnp.asarray([2, 5], jnp.int32)
    out, k2, v2 = attn.decode_attention(q, k_cache, v_cache, k_new, v_new,
                                        pos, rt=RT)
    # row 0 wrote at 2; row 1 wrote at 5
    np.testing.assert_allclose(np.asarray(k2[0, :, 2]),
                               np.asarray(k_new[0]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(k2[1, :, 5]),
                               np.asarray(k_new[1]), rtol=1e-6)
    # row 0's slot 5 untouched
    np.testing.assert_allclose(np.asarray(k2[0, :, 5]),
                               np.asarray(k_cache[0, :, 5]), rtol=1e-6)
    # per-row oracle
    for i, p_i in enumerate([2, 5]):
        kc = k_cache.at[i, :, p_i].set(k_new[i])[i]
        vc = v_cache.at[i, :, p_i].set(v_new[i])[i]
        kr = jnp.repeat(kc, 2, axis=0)
        vr = jnp.repeat(vc, 2, axis=0)
        sc = jnp.einsum("hd,hkd->hk", q[i], kr) / np.sqrt(dh)
        sc = jnp.where(jnp.arange(s) <= p_i, sc, -1e30)
        want = jnp.einsum("hk,hkd->hd", jax.nn.softmax(sc, -1), vr)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
