"""The ragged decode megakernel (ops.paged_decode_ragged) and the serving
engine's fused decode tick (docs/SERVING.md §megakernel):

* interpret-vs-ref kernel parity per KV scheme (dense, uniform8, sp2_8,
  spx_8_x3) across the whole ragged surface — q_len from 0 (inactive
  slot) through the full K+1 verify window, attend_len straddling page
  boundaries — plus exact zeros for padded window rows,
* the non-negotiable invariant: greedy engine outputs with the megakernel
  ON are bit-identical to the unfused per-call decode path, across
  {plain, kv_quant} x {spec on, spec off},
* ONE launch per decode tick: the fused step traces the ragged op exactly
  once, compiles exactly once, and never retraces across ticks with
  varying attend_len / n_valid (no pow2-window padding to bucket on),
* planner sizing (codes+scale pages + resident LUT) and the autotune key
  separating kv_scheme and the spec window,
* knobs: REPRO_FUSED_DECODE=0 opts out, explicit fused_decode=True on a
  dense engine is an error, the env default degrades silently there.

No hypothesis dependency — collected on the bare tier-1 environment.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import spx
from repro.kernels import ops
from repro.models import lm as lm_mod
from repro.nn.attention import quantize_kv
from repro.runtime import Runtime, planner
from repro.serving.engine import Request, ServeConfig, ServeEngine

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=16)

SCHEMES = (None, "uniform8", "sp2_8", "spx_8_x3")


# ---------------------------------------------------------------------------
# Kernel parity: interpret (the Pallas body on CPU) vs the jnp oracle
# ---------------------------------------------------------------------------

def _pools(rng, b, hkv, ps, max_pages, dh, scheme):
    """Random page pools (+1 spare page so block tables can alias), the
    block tables, and the (k, v) pool pair in the layout ``scheme`` asks
    for (dense arrays, or codes+scale dicts)."""
    n_pages = 1 + b * max_pages
    kp = jnp.asarray(rng.standard_normal((n_pages, hkv, ps, dh)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, hkv, ps, dh)),
                     jnp.float32)
    bt = jnp.asarray(rng.integers(0, n_pages, (b, max_pages)), jnp.int32)
    if scheme is None:
        return kp, vp, bt
    kq = dict(zip(("codes", "scale"), quantize_kv(kp, scheme)))
    vq = dict(zip(("codes", "scale"), quantize_kv(vp, scheme)))
    return kq, vq, bt


@pytest.mark.parametrize("scheme", SCHEMES)
def test_ragged_interpret_matches_ref(scheme):
    rng = np.random.default_rng(11)
    b, hq, hkv, dh, ps, mp, w = 4, 4, 2, 32, 8, 4, 4   # verify window K+1=4
    kp, vp, bt = _pools(rng, b, hkv, ps, mp, dh, scheme)
    q = jnp.asarray(rng.standard_normal((b, w, hq, dh)), jnp.float32)
    # ragged surface: q_len 0 (inactive) .. w (full window); ctx at and
    # around a page boundary so the per-slot trip count changes mid-batch
    ctx = jnp.asarray([0, ps - 1, ps, ps + 1], jnp.int32)
    qlen = jnp.asarray([0, 1, 3, w], jnp.int32)
    kw = dict(kv_scheme=scheme) if scheme else {}
    want = ops.paged_decode_ragged(q, kp, vp, bt, ctx, qlen, impl="ref",
                                   **kw)
    got = ops.paged_decode_ragged(q, kp, vp, bt, ctx, qlen,
                                  impl="interpret", **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    # rows past q_len (and the whole inactive slot 0) are EXACT zeros in
    # both impls — the engine relies on never reading garbage there
    for out in (np.asarray(want), np.asarray(got)):
        assert (out[0] == 0).all()
        assert (out[1, 1:] == 0).all()
        assert (out[2, 3:] == 0).all()
        assert (out[3] != 0).any()


def test_ragged_w1_bit_identical_to_paged_attention():
    """W == 1 is plain decode: the ragged ref must equal the existing
    single-token paged-attention ref bit for bit (attend_len = ctx + 1),
    which is what makes fused-vs-unfused greedy outputs identical."""
    rng = np.random.default_rng(5)
    b, hq, hkv, dh, ps, mp = 3, 4, 2, 32, 8, 3
    kp, vp, bt = _pools(rng, b, hkv, ps, mp, dh, None)
    q = jnp.asarray(rng.standard_normal((b, 1, hq, dh)), jnp.float32)
    ctx = jnp.asarray([ps - 1, ps, 2 * ps + 3], jnp.int32)
    ones = jnp.ones((b,), jnp.int32)
    ragged = ops.paged_decode_ragged(q, kp, vp, bt, ctx, ones, impl="ref")
    plain = ops.paged_attention(q[:, 0], kp, vp, bt, ctx + 1, impl="ref")
    assert (np.asarray(ragged[:, 0]) == np.asarray(plain)).all()


def test_ragged_quant_needs_scheme():
    rng = np.random.default_rng(0)
    kq, vq, bt = _pools(rng, 2, 1, 8, 2, 16, "uniform8")
    q = jnp.zeros((2, 1, 2, 16), jnp.float32)
    lens = jnp.ones((2,), jnp.int32)
    with pytest.raises(ValueError, match="kv_scheme"):
        ops.paged_decode_ragged(q, kq, vq, bt, lens, lens, impl="ref")


def test_registry_has_ragged_ops():
    from repro.runtime import registry
    for op in ("paged_decode_ragged", "paged_decode_ragged_quant"):
        assert set(registry.available_impls(op)) >= {"ref", "interpret"}
        assert registry.resolve(op, "auto").impl == "ref"   # CPU


# ---------------------------------------------------------------------------
# One launch per tick + the pow2-padding retrace hazard
# ---------------------------------------------------------------------------

def _tiny_cfg():
    # pinned exact-greedy workload (see tests/test_spec_decode.py): vocab
    # 32 keeps random-init top-2 logit gaps wide, so equality assertions
    # compare decode paths instead of coin-flip near-ties
    return dataclasses.replace(reduced(get_config("gemma-2b"), vocab=32),
                               head_dim=128)


def test_fused_step_single_trace_across_ragged_ticks():
    """The megakernel step compiles ONCE and traces the ragged attention
    op ONCE — varying attend_len / n_valid across ticks rides in the
    scalar-prefetch data, not the trace, so there is no pow2 bucketing
    and no retrace (the Runtime-test discipline, now for raggedness)."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(3), cfg)
    caches = lm_mod.paged_init_caches(cfg, n_pages=8, page_size=8,
                                      dtype=jnp.float32)
    step = jax.jit(lm_mod.lm_paged_fused_step, static_argnums=(7, 8))
    bt = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    w = 4
    tokens = jnp.zeros((2, w), jnp.int32)
    sidx = jnp.zeros((2, 2), jnp.int32)               # attn-only sentinels
    ops.reset_op_calls()
    ticks = [([3, 9], [1, 4]), ([4, 13], [4, 1]),     # ragged + page
             ([8, 14], [2, 3]), ([0, 17], [0, 2])]    # boundary crossings
    for ctx, nv in ticks:
        logits, caches = step(params, tokens, jnp.asarray(ctx, jnp.int32),
                              bt, jnp.asarray(nv, jnp.int32), sidx, caches,
                              cfg, RT)
    assert logits.shape == (2, w, cfg.vocab_size)
    assert step._cache_size() == 1                    # zero retrace
    calls = ops.op_calls()
    # one trace, one ragged-op call site inside it (the layer scan traces
    # its body once) — and the legacy per-call decode ops never appear
    assert calls.get("paged_decode_ragged") == 1
    assert calls.get("paged_attention") is None
    assert calls.get("paged_attention_quant") is None


def test_engine_fused_tick_is_one_compile_one_launch():
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(3), cfg)
    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=2, max_seq=64, quantize=None,
                                  kv_layout="paged", fused_decode=True),
                      rt=RT)
    rng = np.random.default_rng(3)
    for i in range(4):
        p = rng.integers(0, cfg.vocab_size,
                         int(rng.integers(4, 24))).astype(np.int32)
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    ops.reset_op_calls()
    eng.run()
    # ragged prompt lengths + continuous batching varied attend_len and
    # n_valid across every tick; still one compiled fused step ...
    assert eng._fused_step._cache_size() == 1
    # ... whose single trace carried the tick's single ragged launch
    assert ops.op_calls().get("paged_decode_ragged") == 1
    m = eng.metrics()
    assert m["fused_decode"] is True
    assert m["model_calls"] >= 1


# ---------------------------------------------------------------------------
# Engine bit-identity: megakernel on vs off
# ---------------------------------------------------------------------------

def _drive(params, cfg, prompts, *, fused, kv_quant=False, spec=False,
           new_tokens=8):
    rt = dataclasses.replace(RT, kv_quant=kv_quant,
                             kv_scheme="spx_8_x3" if kv_quant else RT.kv_scheme)
    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=2, max_seq=64, quantize="sp2_4",
                                  kv_layout="paged", fused_decode=fused,
                                  spec_decode=True if spec else None,
                                  spec_k=3 if spec else None),
                      rt=rt)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
    out = {r.rid: list(r.output) for r in eng.run()}
    return out, eng.metrics()


@pytest.mark.parametrize("kv_quant", [False, True])
@pytest.mark.parametrize("spec", [False, True])
def test_fused_greedy_bit_identical_to_unfused(kv_quant, spec):
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    # repetition-heavy tails give the n-gram drafter something to accept
    prompts = [np.tile(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                       3) for _ in range(4)]
    fused_out, fm = _drive(params, cfg, prompts, fused=True,
                           kv_quant=kv_quant, spec=spec)
    plain_out, pm = _drive(params, cfg, prompts, fused=False,
                           kv_quant=kv_quant, spec=spec)
    assert fused_out == plain_out
    assert fm["fused_decode"] and not pm["fused_decode"]
    assert fm["tokens_generated"] == pm["tokens_generated"]
    if spec:
        # speculation stays effective through the megakernel: fewer model
        # calls than tokens means some windows accepted drafts
        assert fm["draft_acceptance_rate"] > 0.0


def test_fused_sampled_matches_unfused_key_chain():
    """Temperature sampling: the fused tick consumes the per-request key
    chain exactly like the unfused one (one draw per emitted token), so
    seeded sampled outputs are identical too."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(2)]

    def run(fused):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=2, max_seq=64, quantize=None,
                                      kv_layout="paged", fused_decode=fused),
                          rt=RT)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6,
                               temperature=0.8, seed=17 + i))
        return {r.rid: list(r.output) for r in eng.run()}

    assert run(True) == run(False)


# ---------------------------------------------------------------------------
# Knobs
# ---------------------------------------------------------------------------

def test_fused_decode_knobs(monkeypatch):
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(3), cfg)
    # default ON for paged engines
    assert ServeEngine(
        params, cfg, ServeConfig(quantize=None, kv_layout="paged"),
        rt=RT).fused_decode is True
    # REPRO_FUSED_DECODE=0 flips the default off
    monkeypatch.setenv("REPRO_FUSED_DECODE", "0")
    assert ServeEngine(
        params, cfg, ServeConfig(quantize=None, kv_layout="paged"),
        rt=RT).fused_decode is False
    monkeypatch.delenv("REPRO_FUSED_DECODE")
    # dense engine: the env/default degrades silently ...
    dense = ServeEngine(params, cfg,
                        ServeConfig(quantize=None, kv_layout="dense"), rt=RT)
    assert dense.fused_decode is False
    # ... but an explicit True there is a caller error
    with pytest.raises(ValueError, match="fused_decode"):
        ServeEngine(params, cfg,
                    ServeConfig(quantize=None, kv_layout="dense",
                                fused_decode=True),
                    rt=RT)


# ---------------------------------------------------------------------------
# Planner model + autotune key
# ---------------------------------------------------------------------------

def test_plan_fused_decode_byte_model():
    dense = planner.plan_fused_decode(128, rep=4, w=5, page_size=16,
                                      act_bytes=4)
    quant = planner.plan_fused_decode(128, rep=4, w=5, page_size=16,
                                      act_bytes=4, kv_scheme="spx_8_x3")
    assert dense.rows == quant.rows == 20
    assert dense.lut_bytes == 0
    # 8-bit code schemes: 256-entry f32 LUT resident for the launch
    assert quant.lut_bytes == 4 * 256
    # codes+scale pages stream fewer bytes than f32 pages, so the quant
    # kernel's margin is strictly better at the same window
    assert quant.margin > dense.margin
    assert dense.vmem_bytes > 0 and quant.vmem_bytes > 0
    # a wider window adds compute per streamed page, never load
    w1 = planner.plan_fused_decode(128, rep=4, w=1, page_size=16,
                                   act_bytes=4)
    assert dense.margin > w1.margin


def test_fused_decode_key_separates_scheme_and_window():
    base = dict(b=4, hkv=2, rep=4, dh=128, page_size=16, max_pages=8)
    k_dense = planner.fused_decode_key(w=1, kv_scheme=None, **base)
    k_quant = planner.fused_decode_key(w=1, kv_scheme="spx_8_x3", **base)
    k_verify = planner.fused_decode_key(w=5, kv_scheme=None, **base)
    k_uniform = planner.fused_decode_key(w=1, kv_scheme="uniform8", **base)
    assert len({k_dense, k_quant, k_verify, k_uniform}) == 4
    # and the measured-plan table keys on it: a winner cached for one
    # scheme/window is invisible to the others
    planner.clear_plan_cache()
    plan = planner.plan_fused_decode(128, rep=4, w=1, page_size=16)
    assert planner.measured_best(k_dense, [plan], lambda p: 1.0) is plan
    assert planner.measured_plan(k_dense) is plan
    assert planner.measured_plan(k_quant) is None
    assert planner.measured_plan(k_verify) is None
    planner.clear_plan_cache()
