"""SSM correctness: the chunked-parallel training forms must match the
sequential (decode) recurrences step for step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import ssm
from repro.runtime import Runtime

jax.config.update("jax_platform_name", "cpu")
RT = Runtime(impl="ref", q_chunk=16)


def test_selective_scan_chunked_matches_sequential():
    rng = np.random.default_rng(0)
    B, S, di, ds = 2, 32, 8, 4
    u = jnp.asarray(rng.standard_normal((B, S, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (di, ds)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, ds)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, ds)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((di,)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((B, di, ds)), jnp.float32)

    # sequential oracle
    def step(h, t):
        dA = jnp.exp(dt[:, t, :, None] * A)
        dBu = dt[:, t, :, None] * Bm[:, t, None, :] * u[:, t, :, None]
        h = dA * h + dBu
        y = jnp.einsum("bds,bs->bd", h, Cm[:, t])
        return h, y
    h = h0
    ys = []
    for t in range(S):
        h, y = step(h, t)
        ys.append(y)
    y_seq = jnp.stack(ys, 1) + u * D

    for chunk in (4, 8, 16, 32):
        y_chunk, hT = ssm._selective_scan(u, dt, A, Bm, Cm, D, h0,
                                          chunk=chunk)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4, err_msg=f"c={chunk}")
        np.testing.assert_allclose(np.asarray(hT), np.asarray(h),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_mlstm_chunkwise_matches_cell(chunk):
    rng = np.random.default_rng(1)
    B, S, NH, dh = 2, 32, 2, 8
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q, k, v = mk(B, S, NH, dh), mk(B, S, NH, dh), mk(B, S, NH, dh)
    ig = mk(B, S, NH) * 2
    fg = mk(B, S, NH) * 2 + 1
    C0 = mk(B, NH, dh, dh) * 0.1
    n0 = jnp.abs(mk(B, NH, dh)) * 0.1
    m0 = mk(B, NH) * 0.1

    # sequential oracle via the decode cell
    C, n, m = C0, n0, m0
    hs = []
    for t in range(S):
        C, n, m, h = ssm._mlstm_cell(C, n, m, q[:, t], k[:, t], v[:, t],
                                     ig[:, t], fg[:, t])
        hs.append(h)
    h_seq = jnp.stack(hs, 1)

    h_chunk, Cc, nc_, mc = ssm._mlstm_chunkwise(q, k, v, ig, fg, C0, n0, m0,
                                                chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(Cc), np.asarray(C), rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(mc), np.asarray(m), rtol=3e-4,
                               atol=3e-4)


def test_mamba_apply_prefill_state_continues():
    """prefill(x[:16]) state + decode steps == full forward."""
    rng = np.random.default_rng(2)
    B, S, D = 2, 16, 12
    p = ssm.mamba_init(jax.random.PRNGKey(0), D, d_state=4, d_conv=3,
                       expand=2, dt_rank=4)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y_full = ssm.mamba_apply(p, x, rt=RT)
    y_pre, st = ssm.mamba_apply(p, x[:, :S // 2], rt=RT, return_state=True)
    ys = [y_pre]
    for t in range(S // 2, S):
        y_t, st = ssm.mamba_decode_step(p, x[:, t:t + 1], st, rt=RT)
        ys.append(y_t)
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)


def test_slstm_apply_decode_consistency():
    rng = np.random.default_rng(3)
    B, S, D = 2, 12, 16
    p = ssm.slstm_init(jax.random.PRNGKey(1), D, n_heads=2)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y_full = ssm.slstm_apply(p, x, rt=RT)
    y_pre, st = ssm.slstm_apply(p, x[:, :S // 2], rt=RT, return_state=True)
    ys = [y_pre]
    for t in range(S // 2, S):
        y_t, st = ssm.slstm_decode_step(p, x[:, t:t + 1], st, rt=RT)
        ys.append(y_t)
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Chunk-boundary parity (SSM_CHUNK=128) and the slab-backed paged steps
# ---------------------------------------------------------------------------

def test_mamba_decode_chain_matches_full_scan_across_chunk_boundary():
    """Token-by-token mamba_decode_step chained over lengths that
    straddle SSM_CHUNK=128 must match mamba_apply's chunked full scan —
    the carried (h, conv) state is exact across the chunk seam."""
    rng = np.random.default_rng(10)
    B, D = 2, 8
    p = ssm.mamba_init(jax.random.PRNGKey(4), D, d_state=4, d_conv=4,
                       expand=2, dt_rank=4)
    for S in (ssm.SSM_CHUNK - 1, ssm.SSM_CHUNK, ssm.SSM_CHUNK + 5):
        x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
        y_full = ssm.mamba_apply(p, x, rt=RT)
        st = ssm.mamba_init_state(p, B)
        ys = []
        for t in range(S):
            y_t, st = ssm.mamba_decode_step(p, x[:, t:t + 1], st, rt=RT)
            ys.append(y_t)
        y_cat = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                                   rtol=2e-4, atol=2e-4, err_msg=f"S={S}")


def test_mamba_paged_step_slab_path_matches_full_scan():
    """The slab-backed ragged chunk step chained over uneven chunks that
    straddle SSM_CHUNK=128 matches the full scan, and a masked row
    (n_valid=0) leaves its state bit-identical."""
    rng = np.random.default_rng(11)
    B, D, S = 2, 8, ssm.SSM_CHUNK + 12
    p = ssm.mamba_init(jax.random.PRNGKey(5), D, d_state=4, d_conv=4,
                       expand=2, dt_rank=4)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y_full = ssm.mamba_apply(p, x, rt=RT)
    st = ssm.mamba_init_state(p, B)
    ys, off = [], 0
    for c in (96, 30, 14):
        nv = jnp.full((B,), c, jnp.int32)
        y_c, st = ssm.mamba_paged_step(p, x[:, off:off + c], st, nv, rt=RT)
        ys.append(y_c)
        off += c
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)
    # ragged: row 1 inactive -> its state must be untouched, bit for bit
    st0 = ssm.mamba_init_state(p, B)
    nv = jnp.asarray([5, 0], jnp.int32)
    _, st1 = ssm.mamba_paged_step(p, x[:, :8], st0, nv, rt=RT)
    for k in st0:
        assert bool(jnp.all(st1[k][1] == st0[k][1])), k


def test_mlstm_paged_step_matches_full_scan_across_chunk_boundary():
    rng = np.random.default_rng(12)
    B, D, S = 2, 16, ssm.SSM_CHUNK + 24
    p = ssm.mlstm_init(jax.random.PRNGKey(6), D, n_heads=2)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y_full = ssm.mlstm_apply(p, x, rt=RT, n_heads=2)
    assert not bool(jnp.any(jnp.isnan(y_full)))   # c >= 128 single chunk
    st = ssm.mlstm_init_state(p, B, n_heads=2)
    dc = p["conv_w"].shape[0]
    st = dict(st, conv=jnp.zeros((B, dc - 1, p["conv_w"].shape[1]),
                                 jnp.float32))
    ys, off = [], 0
    for c in (64, 60, 28):
        nv = jnp.full((B,), c, jnp.int32)
        y_c, st = ssm.mlstm_paged_step(p, x[:, off:off + c], st, nv,
                                       rt=RT, n_heads=2)
        ys.append(y_c)
        off += c
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                               rtol=5e-4, atol=5e-4)


def test_slstm_paged_step_matches_full_scan_ragged():
    rng = np.random.default_rng(13)
    B, D, S = 2, 16, 24
    p = ssm.slstm_init(jax.random.PRNGKey(7), D, n_heads=2)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y_full = ssm.slstm_apply(p, x, rt=RT)
    st = ssm.slstm_init_state(p, B)
    ys, off = [], 0
    for c in (10, 9, 5):
        nv = jnp.full((B,), c, jnp.int32)
        y_c, st = ssm.slstm_paged_step(p, x[:, off:off + c], st, nv, rt=RT)
        ys.append(y_c)
        off += c
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)
    # inactive row: state bit-preserved
    st0 = ssm.slstm_init_state(p, B)
    nv = jnp.asarray([3, 0], jnp.int32)
    _, st1 = ssm.slstm_paged_step(p, x[:, :6], st0, nv, rt=RT)
    for k in st0:
        assert bool(jnp.all(st1[k][1] == st0[k][1])), k
