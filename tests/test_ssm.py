"""SSM correctness: the chunked-parallel training forms must match the
sequential (decode) recurrences step for step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import ssm
from repro.runtime import Runtime

jax.config.update("jax_platform_name", "cpu")
RT = Runtime(impl="ref", q_chunk=16)


def test_selective_scan_chunked_matches_sequential():
    rng = np.random.default_rng(0)
    B, S, di, ds = 2, 32, 8, 4
    u = jnp.asarray(rng.standard_normal((B, S, di)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (di, ds)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, ds)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, ds)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((di,)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((B, di, ds)), jnp.float32)

    # sequential oracle
    def step(h, t):
        dA = jnp.exp(dt[:, t, :, None] * A)
        dBu = dt[:, t, :, None] * Bm[:, t, None, :] * u[:, t, :, None]
        h = dA * h + dBu
        y = jnp.einsum("bds,bs->bd", h, Cm[:, t])
        return h, y
    h = h0
    ys = []
    for t in range(S):
        h, y = step(h, t)
        ys.append(y)
    y_seq = jnp.stack(ys, 1) + u * D

    for chunk in (4, 8, 16, 32):
        y_chunk, hT = ssm._selective_scan(u, dt, A, Bm, Cm, D, h0,
                                          chunk=chunk)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4, err_msg=f"c={chunk}")
        np.testing.assert_allclose(np.asarray(hT), np.asarray(h),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_mlstm_chunkwise_matches_cell(chunk):
    rng = np.random.default_rng(1)
    B, S, NH, dh = 2, 32, 2, 8
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q, k, v = mk(B, S, NH, dh), mk(B, S, NH, dh), mk(B, S, NH, dh)
    ig = mk(B, S, NH) * 2
    fg = mk(B, S, NH) * 2 + 1
    C0 = mk(B, NH, dh, dh) * 0.1
    n0 = jnp.abs(mk(B, NH, dh)) * 0.1
    m0 = mk(B, NH) * 0.1

    # sequential oracle via the decode cell
    C, n, m = C0, n0, m0
    hs = []
    for t in range(S):
        C, n, m, h = ssm._mlstm_cell(C, n, m, q[:, t], k[:, t], v[:, t],
                                     ig[:, t], fg[:, t])
        hs.append(h)
    h_seq = jnp.stack(hs, 1)

    h_chunk, Cc, nc_, mc = ssm._mlstm_chunkwise(q, k, v, ig, fg, C0, n0, m0,
                                                chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(Cc), np.asarray(C), rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(mc), np.asarray(m), rtol=3e-4,
                               atol=3e-4)


def test_mamba_apply_prefill_state_continues():
    """prefill(x[:16]) state + decode steps == full forward."""
    rng = np.random.default_rng(2)
    B, S, D = 2, 16, 12
    p = ssm.mamba_init(jax.random.PRNGKey(0), D, d_state=4, d_conv=3,
                       expand=2, dt_rank=4)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y_full = ssm.mamba_apply(p, x, rt=RT)
    y_pre, st = ssm.mamba_apply(p, x[:, :S // 2], rt=RT, return_state=True)
    ys = [y_pre]
    for t in range(S // 2, S):
        y_t, st = ssm.mamba_decode_step(p, x[:, t:t + 1], st, rt=RT)
        ys.append(y_t)
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)


def test_slstm_apply_decode_consistency():
    rng = np.random.default_rng(3)
    B, S, D = 2, 12, 16
    p = ssm.slstm_init(jax.random.PRNGKey(1), D, n_heads=2)
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    y_full = ssm.slstm_apply(p, x, rt=RT)
    y_pre, st = ssm.slstm_apply(p, x[:, :S // 2], rt=RT, return_state=True)
    ys = [y_pre]
    for t in range(S // 2, S):
        y_t, st = ssm.slstm_decode_step(p, x[:, t:t + 1], st, rt=RT)
        ys.append(y_t)
    y_cat = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                               rtol=1e-4, atol=1e-4)
