"""Serving engine: continuous batching, per-slot positions, quantized
weights; decode agrees with the model's full forward. Paged KV layout:
identical greedy outputs vs the dense layout, page-budget admission
(queued, not crashed), reclaim-unblocks-admission, and paged-vs-dense
logits agreement at the model level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving.engine import Request, ServeConfig, ServeEngine

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=16)


def _tiny_cfg():
    return reduced(get_config("granite-3-8b"))


def test_engine_drains_queue_quantized():
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=3, max_seq=64, quantize="sp2_8"),
                      rt=RT)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5 + i)
                    .astype(np.int32), max_new_tokens=6) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run()
    assert len(finished) == 7
    for r in finished:
        assert len(r.output) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.output)
        assert r.t_first_token >= r.t_enqueue


def test_engine_greedy_matches_reference_decode():
    """Engine (batched slots, quantize=None) greedy output == hand-rolled
    single-sequence prefill+decode."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)

    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=2, max_seq=32, quantize=None),
                      rt=RT)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    out_engine = eng.run()[0].output

    # reference: single-row decode
    caches = lm_mod.init_caches(cfg, 1, 32, dtype=jnp.float32)
    logits, caches = lm_mod.lm_prefill(
        params, jnp.asarray(prompt)[None, :], caches, cfg, RT)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(4):
        logits, caches = lm_mod.lm_decode_step(
            params, jnp.asarray([toks[-1]], jnp.int32), jnp.int32(pos),
            caches, cfg, RT)
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    assert out_engine == toks


def test_per_slot_positions_independent():
    """Two requests of different lengths decoding in lockstep must not
    interfere (per-slot positions)."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)

    def solo(prompt, n=4):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=1, max_seq=32,
                                      quantize=None), rt=RT)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=n))
        return eng.run()[0].output

    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=2, max_seq=32, quantize=None),
                      rt=RT)
    eng.submit(Request(rid=0, prompt=p1, max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=p2, max_new_tokens=4))
    both = {r.rid: r.output for r in eng.run()}
    assert both[0] == solo(p1)
    assert both[1] == solo(p2)


def test_paged_matches_dense_engine_mixed_lengths():
    """Acceptance: the paged engine (chunked prefill + block-table decode)
    produces identical greedy outputs to the dense engine on a mixed-length
    request batch (ref backend)."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 9, 17, 6, 12)]

    def drive(layout, **kw):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=2, max_seq=32, quantize=None,
                                      kv_layout=layout, **kw),
                          rt=RT)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        return {r.rid: r.output for r in eng.run()}, eng

    dense, _ = drive("dense")
    paged, eng = drive("paged", prefill_chunk=8)
    assert eng.kv_layout == "paged"
    assert dense == paged
    m = eng.metrics()
    assert m["requests_finished"] == 5
    assert 0.0 < m["occupancy_peak"] <= 1.0
    assert m["peak_kv_bytes"] > 0


def test_paged_chunk_size_invariance():
    """Chunked prefill is a scheduling choice, not a model change: outputs
    are identical whether the prompt streams in 4-token chunks or lands in
    one chunk."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)

    def drive(chunk):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                      kv_layout="paged", prefill_chunk=chunk),
                          rt=RT)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        return eng.run()[0].output

    assert drive(4) == drive(32)


def test_page_budget_admission_queues_then_reclaims():
    """A request whose worst-case footprint exceeds the free pages stays
    queued (not crashed, not evicting); the page reclaim when the running
    request finishes makes it admissible."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(6), cfg)
    rng = np.random.default_rng(6)
    p1 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)

    def solo(prompt):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                      kv_layout="paged", page_size=8,
                                      pool_pages=2),
                          rt=RT)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
        return eng.run()[0].output

    # pool of 2 pages x 8 tokens: each request needs 2 pages (10 + 5
    # tokens) -> only one sequence fits at a time despite 2 slots
    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=2, max_seq=32, quantize=None,
                                  kv_layout="paged", page_size=8,
                                  pool_pages=2),
                      rt=RT)
    r1 = Request(rid=0, prompt=p1, max_new_tokens=5)
    r2 = Request(rid=1, prompt=p2, max_new_tokens=5)
    eng.submit(r1)
    eng.submit(r2)
    done = {r.rid: r for r in eng.run()}
    assert set(done) == {0, 1}
    # one denied *sequence*, however many ticks it waited
    assert eng.pool.stats.admission_denials == 1
    assert done[0].t_done <= done[1].t_first_token       # admitted after
    assert eng.pool.free_pages() == 2                    # all reclaimed
    # backpressure must not change the outputs
    assert done[0].output == solo(p1)
    assert done[1].output == solo(p2)


def test_paged_vs_dense_decode_logits_agree():
    """Model-level: lm_paged_step (prefill chunk + decode steps) matches
    the dense lm_prefill/lm_decode_step logits on the ref backend."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(7), cfg)
    rng = np.random.default_rng(7)
    plen, n_dec, max_seq, ps = 9, 4, 32, 8
    prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    toks = jnp.asarray(prompt)[None, :]

    # dense reference
    caches = lm_mod.init_caches(cfg, 1, max_seq, dtype=jnp.float32)
    d_logits, caches = lm_mod.lm_prefill(params, toks, caches, cfg, RT)

    # paged: whole prompt as one chunk, identity block table
    n_pages = max_seq // ps
    pcaches = lm_mod.paged_init_caches(cfg, n_pages, ps, dtype=jnp.float32)
    bt = jnp.arange(n_pages, dtype=jnp.int32)[None, :]
    sidx = jnp.zeros((1, 2), jnp.int32)      # attn-only: sentinel row
    p_logits, pcaches = lm_mod.lm_paged_step(
        params, toks, jnp.zeros(1, jnp.int32), bt,
        jnp.asarray([plen], jnp.int32), sidx, pcaches, cfg, RT)
    np.testing.assert_allclose(np.asarray(d_logits), np.asarray(p_logits),
                               atol=1e-4)

    pos = plen
    tok = int(jnp.argmax(d_logits[0]))
    for _ in range(n_dec):
        d_logits, caches = lm_mod.lm_decode_step(
            params, jnp.asarray([tok], jnp.int32), jnp.int32(pos),
            caches, cfg, RT)
        p_logits, pcaches = lm_mod.lm_paged_step(
            params, jnp.asarray([[tok]], jnp.int32),
            jnp.asarray([pos], jnp.int32), bt,
            jnp.ones(1, jnp.int32), sidx, pcaches, cfg, RT)
        np.testing.assert_allclose(np.asarray(d_logits),
                                   np.asarray(p_logits), atol=1e-4)
        tok = int(jnp.argmax(d_logits[0]))
        pos += 1


def test_prefix_cache_matches_uncached_and_saves_pages():
    """Acceptance: on a batch of requests sharing a page-aligned system
    prompt, greedy outputs are identical with the prefix cache on vs off,
    prefill work is actually skipped (including one COW for a bare
    page-aligned duplicate prompt), and the peak page count is strictly
    lower with sharing."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(10), cfg)
    rng = np.random.default_rng(10)
    sys_prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    prompts = [sys_prompt.copy()]                     # primer
    prompts += [np.concatenate(
        [sys_prompt, rng.integers(0, cfg.vocab_size, n).astype(np.int32)])
        for n in (3, 5, 1)]
    prompts.append(sys_prompt.copy())                 # full match -> COW

    def drive(on):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=2, max_seq=48, quantize=None,
                                      kv_layout="paged", page_size=8,
                                      prefix_cache=on),
                          rt=RT)
        eng.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=4))
        eng.run()                                     # prime the pool
        for i, p in enumerate(prompts[1:], start=1):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        eng.run()
        eng.pool.validate()
        return {r.rid: r.output for r in eng.finished}, eng.metrics(), eng

    out_off, m_off, _ = drive(False)
    out_on, m_on, eng = drive(True)
    assert out_on == out_off
    assert m_off["prefill_tokens_skipped"] == 0
    assert m_on["prefill_tokens_skipped"] > 0
    assert m_on["prefix_hits"] == 4                   # every post-primer req
    assert m_on["cow_copies"] == 1                    # the bare duplicate
    assert m_on["peak_kv_pages"] < m_off["peak_kv_pages"]
    # every page reclaimed once all owners finished
    assert eng.pool.free_pages() == eng.pool.n_pages
    assert eng.pool.stats.pages_in_use == 0


def test_prefix_cache_rejected_on_dense_layout():
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(11), cfg)
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeEngine(params, cfg,
                    ServeConfig(batch_slots=1, max_seq=16, quantize=None,
                                kv_layout="dense", prefix_cache=True),
                    rt=RT)


def test_submit_rejects_oversized_request():
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(8), cfg)
    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=1, max_seq=16, quantize=None),
                      rt=RT)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0,
                           prompt=np.zeros(14, np.int32),
                           max_new_tokens=8))
    # a request that fits max_seq but could NEVER fit the page pool must
    # be rejected at submit, not spin in the queue forever
    tiny = ServeEngine(params, cfg,
                       ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                   kv_layout="paged", page_size=8,
                                   pool_pages=1),
                       rt=RT)
    with pytest.raises(ValueError):
        tiny.submit(Request(rid=1, prompt=np.zeros(10, np.int32),
                            max_new_tokens=5))
    # duplicate rids key the page allocator — rejected while in flight
    paged = ServeEngine(params, cfg,
                        ServeConfig(batch_slots=2, max_seq=32, quantize=None,
                                    kv_layout="paged"),
                        rt=RT)
    paged.submit(Request(rid=2, prompt=np.zeros(4, np.int32),
                         max_new_tokens=2))
    with pytest.raises(ValueError):
        paged.submit(Request(rid=2, prompt=np.zeros(4, np.int32),
                             max_new_tokens=2))


def test_max_new_tokens_one_respected():
    """The first token (emitted at prefill completion) counts toward
    max_new_tokens — a request for 1 token gets exactly 1, both layouts."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(9), cfg)
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size
    for layout in ("dense", "paged"):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                      kv_layout=layout),
                          rt=RT)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
        out = eng.run()[0].output
        assert len(out) == 1, (layout, out)


def test_quantized_serving_close_to_dense():
    """8-bit SPx weights perturb logits but preserve top-1 on most steps —
    the paper's accuracy claim at serving time."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    from repro.nn.layers import quantize_params
    dense_logits = lm_mod.lm_logits(params, tokens, cfg, RT)
    q_logits = lm_mod.lm_logits(quantize_params(params, "sp2_8"), tokens,
                                cfg, RT)
    agree = jnp.mean((jnp.argmax(dense_logits, -1)
                      == jnp.argmax(q_logits, -1)).astype(jnp.float32))
    assert float(agree) > 0.8, float(agree)
