"""Serving engine: continuous batching, per-slot positions, quantized
weights; decode agrees with the model's full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm as lm_mod
from repro.nn.layers import Runtime
from repro.serving.engine import Request, ServeEngine

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=16)


def _tiny_cfg():
    return reduced(get_config("granite-3-8b"))


def test_engine_drains_queue_quantized():
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_slots=3, max_seq=64,
                      quantize="sp2_8", rt=RT)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5 + i)
                    .astype(np.int32), max_new_tokens=6) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run()
    assert len(finished) == 7
    for r in finished:
        assert len(r.output) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.output)
        assert r.t_first_token >= r.t_enqueue


def test_engine_greedy_matches_reference_decode():
    """Engine (batched slots, quantize=None) greedy output == hand-rolled
    single-sequence prefill+decode."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)

    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=32, quantize=None,
                      rt=RT)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    out_engine = eng.run()[0].output

    # reference: single-row decode
    caches = lm_mod.init_caches(cfg, 1, 32, dtype=jnp.float32)
    logits, caches = lm_mod.lm_prefill(
        params, jnp.asarray(prompt)[None, :], caches, cfg, RT)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(4):
        logits, caches = lm_mod.lm_decode_step(
            params, jnp.asarray([toks[-1]], jnp.int32), jnp.int32(pos),
            caches, cfg, RT)
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    assert out_engine == toks


def test_per_slot_positions_independent():
    """Two requests of different lengths decoding in lockstep must not
    interfere (per-slot positions)."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)

    def solo(prompt, n=4):
        eng = ServeEngine(params, cfg, batch_slots=1, max_seq=32,
                          quantize=None, rt=RT)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=n))
        return eng.run()[0].output

    eng = ServeEngine(params, cfg, batch_slots=2, max_seq=32, quantize=None,
                      rt=RT)
    eng.submit(Request(rid=0, prompt=p1, max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=p2, max_new_tokens=4))
    both = {r.rid: r.output for r in eng.run()}
    assert both[0] == solo(p1)
    assert both[1] == solo(p2)


def test_quantized_serving_close_to_dense():
    """8-bit SPx weights perturb logits but preserve top-1 on most steps —
    the paper's accuracy claim at serving time."""
    cfg = _tiny_cfg()
    params = lm_mod.lm_init(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    from repro.nn.layers import quantize_params
    dense_logits = lm_mod.lm_logits(params, tokens, cfg, RT)
    q_logits = lm_mod.lm_logits(quantize_params(params, "sp2_8"), tokens,
                                cfg, RT)
    agree = jnp.mean((jnp.argmax(dense_logits, -1)
                      == jnp.argmax(q_logits, -1)).astype(jnp.float32))
    assert float(agree) > 0.8, float(agree)
