"""Prompt-lookup speculative decoding (serving/spec.py + the engine's
draft-and-verify loop, docs/SERVING.md):

* the non-negotiable invariant — greedy outputs with speculation ON are
  identical to speculation OFF on the pinned vocab=32/dh=128/seed-3
  workload, across paged and paged+kv_quant, prefix cache on and off —
  and this holds for ANY drafter (stubs proposing garbage included:
  verification makes draft quality a throughput knob, never a
  correctness one),
* accept/rollback edges: rejection at position 0, full-window acceptance
  (oracle drafter), rollback across a page boundary, max_new_tokens
  truncation (never emits past the cap),
* per-request seeded sampling: temperature>0 outputs are invariant to
  batch composition and pinned by Request.seed,
* knobs: REPRO_SPEC_K enables with that window, dense engines reject an
  explicit spec_decode=True and silently drop an env-enabled one.

No hypothesis dependency — collected on the bare tier-1 environment.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving.engine import Request, ServeConfig, ServeEngine
from repro.serving.spec import PromptLookupDrafter

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=16)


def _serving_cfg():
    # the pinned exact-greedy workload (see tests/test_kv_quant.py):
    # vocab=32 keeps random-init top-2 logit gaps wide, so the equality
    # assertions compare decode paths instead of coin-flip near-ties
    return dataclasses.replace(reduced(get_config("gemma-2b"), vocab=32),
                               head_dim=128)


def _params(cfg):
    return lm_mod.lm_init(jax.random.PRNGKey(3), cfg)


def _prompts(cfg, n=4, reps=3):
    # repetition-heavy (tiled motifs): the n-gram drafter has something
    # to find, so the acceptance counters are exercised, not just defined
    rng = np.random.default_rng(3)
    return [np.tile(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    reps) for _ in range(n)]


def _drive(params, cfg, prompts, *, spec, new_tokens=8, drafter=None,
           rt=RT, **kw):
    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=2, max_seq=64, quantize=None,
                                  kv_layout="paged", spec_decode=spec, **kw),
                      rt=rt)
    if drafter is not None:
        eng.drafter = drafter
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
    out = {r.rid: r.output for r in eng.run()}
    return out, eng.metrics()


# ---------------------------------------------------------------------------
# Drafter unit behavior (host-side, no model)
# ---------------------------------------------------------------------------

def test_drafter_proposes_latest_continuation():
    d = PromptLookupDrafter(ngram_max=3, ngram_min=1)
    d.start(0, [1, 2, 3, 9, 1, 2, 3, 7, 1, 2, 3])
    # tail (1,2,3) last occurred (interior) at 4..6, continuation 7...
    assert d.propose(0, 4) == [7, 1, 2, 3]
    assert d.propose(0, 1) == [7]                  # k caps the proposal
    d.extend(0, 9)
    # tail ...3,9 matches positions 2..3, continuation 1,2 ...
    assert d.propose(0, 2) == [1, 2]
    assert d.propose(0, 0) == []


def test_drafter_novel_tail_proposes_nothing():
    d = PromptLookupDrafter()
    d.start(0, [5, 6, 7, 8])                       # no repetition at all
    assert d.propose(0, 4) == []
    d.extend(0, 5)
    # tail 1-gram 5 occurred at 0, continuation 6: proposals resume
    assert d.propose(0, 2) == [6, 7]


def test_drafter_lifecycle_errors():
    d = PromptLookupDrafter()
    d.start(0, [1, 1])
    with pytest.raises(KeyError):
        d.start(0, [2])
    d.drop(0)
    d.drop(0)                                      # idempotent
    with pytest.raises(KeyError):
        d.propose(0, 2)
    with pytest.raises(ValueError):
        PromptLookupDrafter(ngram_max=0)


# ---------------------------------------------------------------------------
# The invariant: spec-on greedy == spec-off, every cache configuration
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_quant,prefix", [(False, False), (False, True),
                                             (True, False), (True, True)])
def test_spec_greedy_matches_nonspec_pinned(kv_quant, prefix):
    cfg = _serving_cfg()
    params = _params(cfg)
    prompts = _prompts(cfg)
    rt = RT.replace(kv_quant=True, kv_scheme="spx_8_x3") if kv_quant else RT
    kw = dict(rt=rt, page_size=8, prefix_cache=prefix)
    off, m_off = _drive(params, cfg, prompts, spec=False, **kw)
    on, m_on = _drive(params, cfg, prompts, spec=True, spec_k=4, **kw)
    assert on == off
    # repetition-heavy workload: speculation must actually pay
    assert m_on["model_calls"] < m_off["model_calls"]
    assert m_on["draft_acceptance_rate"] > 0
    assert m_on["spec_decode"] and m_on["spec_k"] == 4
    assert not m_off["spec_decode"]
    assert m_on["tokens_generated"] == m_off["tokens_generated"]


# ---------------------------------------------------------------------------
# Accept/rollback edges via stub drafters (correctness is drafter-free)
# ---------------------------------------------------------------------------

class _StubDrafter:
    """Engine-facing drafter driven by fn(rid, n_emitted, k) -> tokens."""

    def __init__(self, fn):
        self.fn = fn
        self.emitted: dict[int, int] = {}

    def start(self, rid, prompt):
        self.emitted[rid] = 0

    def extend(self, rid, tok):
        self.emitted[rid] += 1

    def drop(self, rid):
        self.emitted.pop(rid, None)

    def propose(self, rid, k):
        return list(self.fn(rid, self.emitted[rid], k))[:k]


def test_rejection_at_position_zero_yields_correction():
    """A drafter that is always wrong at position 0: zero drafts survive,
    every emitted token is the verify correction — outputs must still
    equal non-speculative greedy exactly."""
    cfg = _serving_cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, n=2)
    off, m_off = _drive(params, cfg, prompts, spec=False)
    wrong = _StubDrafter(
        lambda rid, n, k: [(off[rid][n] + 1) % cfg.vocab_size] * k)
    on, m = _drive(params, cfg, prompts, spec=True, spec_k=4,
                   drafter=wrong)
    assert on == off
    assert m["draft_acceptance_rate"] == 0.0
    assert m["accepted_per_step"] == 0.0
    # no acceptance -> one emitted token per verify window, same call
    # count as plain decode
    assert m["model_calls"] == m_off["model_calls"]


def test_oracle_drafter_full_window_acceptance():
    """A drafter that proposes the exact future output: every window is
    fully accepted, acceptance rate is 1.0, and the engine strictly
    beats one-call-per-token."""
    cfg = _serving_cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, n=2)
    off, m_off = _drive(params, cfg, prompts, spec=False, new_tokens=9)
    oracle = _StubDrafter(lambda rid, n, k: off[rid][n:n + k])
    on, m = _drive(params, cfg, prompts, spec=True, spec_k=4,
                   drafter=oracle, new_tokens=9)
    assert on == off
    assert m["draft_acceptance_rate"] == 1.0
    assert m["model_calls"] < m_off["model_calls"]
    # 8 post-first tokens per request at K=4: each window emits K+1=5
    # then the final 3 (draft room shrinks near the cap) -> 2 windows,
    # lockstep across the two slots
    assert m["engine_steps"] < m_off["engine_steps"]


def test_rollback_across_page_boundary():
    """Acceptance stops mid-window with the rejected tail already written
    across a page boundary; the cursor rolls back over the boundary and
    later windows overwrite the stale slots. Outputs must be exact."""
    cfg = _serving_cfg()
    params = _params(cfg)
    prompts = [np.tile(np.arange(3, dtype=np.int32) % cfg.vocab_size, 2)]
    off, _ = _drive(params, cfg, prompts, spec=False, new_tokens=12,
                    page_size=4)
    # prompt len 6, page_size 4: first verify window writes positions
    # 6..11 -> pages 1 and 2; accept exactly one draft (corrupt index 1),
    # so slot_pos rolls back to 8 = the page-2 boundary itself
    def corrupt_at_1(rid, n, k):
        toks = list(off[rid][n:n + k])
        if len(toks) > 1:
            toks[1] = (toks[1] + 1) % cfg.vocab_size
        return toks
    on, m = _drive(params, cfg, prompts, spec=True, spec_k=5,
                   drafter=_StubDrafter(corrupt_at_1), new_tokens=12,
                   page_size=4)
    assert on == off
    assert 0 < m["draft_acceptance_rate"] < 1.0


def test_spec_never_emits_past_max_new_tokens():
    """Draft room shrinks to the emission cap: a huge K with a tiny
    max_new_tokens emits exactly max_new_tokens, and the windows never
    write past the worst-case page reservation."""
    cfg = _serving_cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, n=2)
    for new_tokens in (1, 2, 3):
        off, _ = _drive(params, cfg, prompts, spec=False,
                        new_tokens=new_tokens)
        on, _ = _drive(params, cfg, prompts, spec=True, spec_k=8,
                       new_tokens=new_tokens)
        assert on == off
        for out in on.values():
            assert len(out) == new_tokens


# ---------------------------------------------------------------------------
# Per-request seeded sampling (batch-composition invariance)
# ---------------------------------------------------------------------------

def _sampled(params, cfg, batch, *, engine_seed=0, slots=3):
    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=slots, max_seq=64, quantize=None,
                                  kv_layout="paged", seed=engine_seed),
                      rt=RT)
    for rid, prompt, seed in batch:
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=6,
                           temperature=0.8, seed=seed))
    return {r.rid: r.output for r in eng.run()}


def test_sampled_output_invariant_to_batch_composition():
    cfg = _serving_cfg()
    params = _params(cfg)
    ps = _prompts(cfg, n=3)
    solo = _sampled(params, cfg, [(0, ps[0], None)])
    crowd = _sampled(params, cfg, [(7, ps[1], None), (0, ps[0], None),
                                   (9, ps[2], None)])
    # same rid + engine seed -> same key chain, whoever shares the batch
    assert solo[0] == crowd[0]
    # an explicit Request.seed pins the output across ENGINE seeds too
    a = _sampled(params, cfg, [(0, ps[0], 123)], engine_seed=1)
    b = _sampled(params, cfg, [(0, ps[0], 123)], engine_seed=2)
    assert a[0] == b[0]
    # ... and different rids with no explicit seed draw different chains
    c = _sampled(params, cfg, [(0, ps[0], None), (1, ps[0], None)])
    assert c[0] != c[1]


def test_spec_sampled_is_deterministic():
    """temperature>0 under speculation: rejection sampling draws from the
    per-request chain, so a rerun of the same engine config reproduces
    the outputs token-for-token."""
    cfg = _serving_cfg()
    params = _params(cfg)
    ps = _prompts(cfg, n=2)

    def run():
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=2, max_seq=64, quantize=None,
                                      kv_layout="paged", spec_decode=True,
                                      spec_k=4, seed=5),
                          rt=RT)
        for i, p in enumerate(ps):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=8,
                               temperature=0.8))
        return {r.rid: r.output for r in eng.run()}

    assert run() == run()


# ---------------------------------------------------------------------------
# Knobs
# ---------------------------------------------------------------------------

def test_spec_knobs(monkeypatch):
    cfg = _serving_cfg()
    params = _params(cfg)
    monkeypatch.setenv("REPRO_SPEC_K", "3")
    eng = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                  kv_layout="paged"),
                      rt=RT)
    assert eng.spec_k == 3                        # env enables + sizes
    # env-enabled speculation degrades silently for a dense engine...
    dense = ServeEngine(params, cfg,
                        ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                    kv_layout="dense"),
                        rt=RT)
    assert dense.spec_k == 0
    monkeypatch.delenv("REPRO_SPEC_K")
    off = ServeEngine(params, cfg,
                      ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                  kv_layout="paged"),
                      rt=RT)
    assert off.spec_k == 0
    # ... but an explicit spec_decode=True there is a caller error
    with pytest.raises(ValueError, match="spec_decode"):
        ServeEngine(params, cfg,
                    ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                kv_layout="dense", spec_decode=True),
                    rt=RT)
    # an explicit zero/negative window is an error, not a silent default
    for bad_k in (0, -1):
        with pytest.raises(ValueError, match="spec_k"):
            ServeEngine(params, cfg,
                        ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                    kv_layout="paged", spec_decode=True,
                                    spec_k=bad_k),
                        rt=RT)
    # spec_k alone implies spec_decode (a window size IS the intent —
    # silently ignoring it would benchmark speculation that never ran)
    implied = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                      kv_layout="paged", spec_k=2),
                          rt=RT)
    assert implied.spec_k == 2
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(params, cfg,
                    ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                kv_layout="paged", spec_decode=False,
                                spec_k=2),
                    rt=RT)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(params, cfg,
                    ServeConfig(batch_slots=1, max_seq=32, quantize=None,
                                kv_layout="dense", spec_k=2),
                    rt=RT)


def test_all_novel_tick_degrades_to_plain_decode():
    """A drafter that never proposes: the engine must fall back to the
    one-token decode step (no verify windows at all), with outputs equal
    to spec-off and the same model-call count."""
    cfg = _serving_cfg()
    params = _params(cfg)
    prompts = _prompts(cfg, n=2)
    off, m_off = _drive(params, cfg, prompts, spec=False)
    on, m = _drive(params, cfg, prompts, spec=True, spec_k=4,
                   drafter=_StubDrafter(lambda rid, n, k: []))
    assert on == off
    assert m["model_calls"] == m_off["model_calls"]
    assert m["accepted_per_step"] == 0.0
    assert m["draft_acceptance_rate"] == 0.0
