"""Tier-1 must collect and run on a bare environment (jax + numpy + pytest
only): property-based modules are skipped — not errored — when hypothesis
is missing. Install the `[test]` extra to run them."""
import importlib.util

_HYPOTHESIS_MODULES = ["test_attention.py", "test_spx_quant.py"]

collect_ignore = (
    [] if importlib.util.find_spec("hypothesis") is not None
    else list(_HYPOTHESIS_MODULES))
