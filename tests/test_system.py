"""End-to-end behaviour tests for the paper's system: the §4.1 experiment
(MLP learns digits; SPx-quantized deployment preserves accuracy) and the
LM substrate learning synthetic structure."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.mnist import SynthDigits
from repro.data.tokens import TokenStream, markov_batch
from repro.models.mlp_mnist import (paper_mlp_init, paper_mlp_loss,
                                    paper_mlp_predict)
from repro.nn.layers import quantize_params
from repro.runtime import Runtime
from repro.training import make_optimizer

jax.config.update("jax_platform_name", "cpu")


def _train_paper_mlp(steps=300, seed=0):
    data = SynthDigits(n_train=4096, n_test=512, batch_size=64, seed=seed)
    params = paper_mlp_init(jax.random.PRNGKey(seed))
    opt = make_optimizer("sgd", lr=0.5)       # paper: eta = 0.5
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(paper_mlp_loss)(params, x, y)
        params, state = opt.update(params, grads, state)
        return params, state, loss

    it = data.batches(epochs=100)
    for _ in range(steps):
        x, y = next(it)
        params, state, loss = step(params, state, jnp.asarray(x),
                                   jnp.asarray(y))
    return params, data


def _acc(params, data, rt=None):
    pred = paper_mlp_predict(params, jnp.asarray(data.x_test), rt)
    return float(jnp.mean((pred == jnp.asarray(data.y_test))
                          .astype(jnp.float32)))


def test_paper_mlp_learns_digits():
    """§4.1: the 784-128-10 sigmoid MLP + MSE + SGD(0.5) reaches high
    accuracy on the digit task."""
    params, data = _train_paper_mlp()
    assert _acc(params, data) > 0.9


def test_quantized_deployment_preserves_accuracy():
    """§3.2 + Table 1: SPx-quantized inference matches float accuracy
    within 2 points at 4 bits, 1 point at 8 bits."""
    params, data = _train_paper_mlp()
    base = _acc(params, data)
    rt = Runtime(impl="auto")
    for scheme, tol in (("sp2_8", 0.01), ("spx_8_x3", 0.01),
                        ("sp2_4", 0.02), ("pot4", 0.03)):
        qp = quantize_params(params, scheme, min_size=1024)
        acc = _acc(qp, data, rt)
        assert acc > base - tol, (scheme, acc, base)


def test_sp2_beats_pot_on_gaussian_weights():
    """The paper's central quantization claim (§3.2): PoT's levels collapse
    toward 0, starving the body/tail of a Gaussian weight distribution —
    SP2's extra mid/tail levels recover several dB of SNR at 4 bits.
    (On extremely heavy-tailed data the log-spaced PoT wins instead — the
    trade-off the paper's x-term knob navigates.)"""
    from repro.core.quantized import dequantize, quantize_weight
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((512, 512)) * 0.02, jnp.float32)

    def snr(scheme):
        qt = quantize_weight(w, scheme, pack=False)
        err = jnp.linalg.norm(dequantize(qt, jnp.float32) - w)
        return float(20 * jnp.log10(jnp.linalg.norm(w) / err))

    assert snr("sp2_4") > snr("pot4") + 2.0


def test_lm_learns_markov_structure():
    """The transformer substrate trains: loss on an order-2 Markov stream
    drops well below the uniform baseline within 150 steps."""
    from repro.configs import get_config, reduced
    from repro.models import lm as lm_mod

    cfg = reduced(get_config("granite-3-8b"), d_model=128, vocab=256)
    rt = Runtime(impl="ref", q_chunk=64)
    stream = TokenStream(cfg.vocab_size, 16, 64, branch=4, seed=0)
    params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw", lr=3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: lm_mod.lm_loss(p, batch, cfg, rt), has_aux=True)(params)
        params, state = opt.update(params, grads, state)
        return params, state, loss

    losses = []
    try:
        for i, batch in zip(range(150), stream):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, state, loss = step(params, state, batch)
            losses.append(float(loss))
    finally:
        stream.close()
    uniform = np.log(cfg.vocab_size)          # 5.55
    # order-2 markov with branch 4 has entropy ~ log(4) = 1.39
    assert np.mean(losses[-10:]) < uniform * 0.75, np.mean(losses[-10:])
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.7


def test_markov_stream_is_learnable_structure():
    nexts_rng = np.random.default_rng(0)
    seqs = markov_batch(nexts_rng,
                        np.array([[1, 1], [2, 2], [0, 0]]), 4, 32)
    # deterministic chain: token 0 always followed by 1
    assert seqs.shape == (4, 33)
    assert np.all(seqs[:, 1:][seqs[:, :-1] == 0] == 1)
