"""Per-architecture smoke tests: reduced (family-preserving) configs run one
forward/train step on CPU; output shapes are checked and outputs must be
finite. Also checks prefill+decode consistency against the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import assigned_archs, get_config, reduced
from repro.models import lm as lm_mod
from repro.models import encdec as ed_mod
from repro.nn.layers import param_count
from repro.runtime import Runtime

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=16)
B, S = 2, 32


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(k2, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 3), (B, cfg.enc_seq_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", assigned_archs())
def test_train_step_smoke(name):
    cfg = reduced(get_config(name))
    key = jax.random.PRNGKey(0)
    if cfg.enc_dec:
        params = ed_mod.encdec_init(key, cfg)
        loss, metrics = ed_mod.encdec_loss(params, _batch(cfg, key), cfg, RT)
    else:
        params = lm_mod.lm_init(key, cfg)
        loss, metrics = lm_mod.lm_loss(params, _batch(cfg, key), cfg, RT)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (name, float(loss))
    # one gradient step must also be finite
    if cfg.enc_dec:
        g = jax.grad(lambda p: ed_mod.encdec_loss(p, _batch(cfg, key), cfg,
                                                  RT)[0])(params)
    else:
        g = jax.grad(lambda p: lm_mod.lm_loss(p, _batch(cfg, key), cfg,
                                              RT)[0])(params)
    flat = jax.tree_util.tree_leaves(g)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in flat), name


@pytest.mark.parametrize("name", assigned_archs())
def test_prefill_decode_matches_forward(name):
    """Decode path (KV caches / SSM states) must reproduce the train-mode
    forward logits position by position."""
    cfg = reduced(get_config(name))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    if cfg.enc_dec:
        params = ed_mod.encdec_init(key, cfg)
        frames = jax.random.normal(jax.random.fold_in(key, 3),
                                   (B, cfg.enc_seq_len, cfg.d_model))
        caches = ed_mod.encdec_init_caches(cfg, B, S, dtype=jnp.float32)
        logits_pre, caches = ed_mod.encdec_prefill(
            params, frames, tokens[:, :S // 2], caches, cfg, RT)
        step_logits = [logits_pre]
        for t in range(S // 2, S):
            lg, caches = ed_mod.encdec_decode_step(
                params, tokens[:, t], jnp.int32(t), caches, cfg, RT)
            step_logits.append(lg)
        # full forward for reference
        enc_out = ed_mod.encdec_encode(params, frames, cfg, RT)
        from repro.nn.transformer import stack_apply
        from repro.nn.layers import embedding_apply, norm_apply
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = embedding_apply(params["embed"], tokens)
        h, _ = stack_apply(params["dec_stack"], x, pos, ed_mod.dec_cfg(cfg),
                           RT, enc_out=enc_out)
        h = norm_apply(cfg.norm, params["final_norm"], h)
        full = jnp.einsum("bsd,dv->bsv", h, params["head"]["w"])
    else:
        params = lm_mod.lm_init(key, cfg)
        caches = lm_mod.init_caches(cfg, B, S, dtype=jnp.float32)
        logits_pre, caches = lm_mod.lm_prefill(params, tokens[:, :S // 2],
                                               caches, cfg, RT)
        step_logits = [logits_pre]
        for t in range(S // 2, S):
            lg, caches = lm_mod.lm_decode_step(params, tokens[:, t],
                                               jnp.int32(t), caches, cfg, RT)
            step_logits.append(lg)
        full = lm_mod.lm_logits(params, tokens, cfg, RT)

    # prefill's last logit == full forward at position S//2 - 1
    np.testing.assert_allclose(np.asarray(step_logits[0]),
                               np.asarray(full[:, S // 2 - 1]),
                               rtol=2e-3, atol=2e-3, err_msg=f"{name} prefill")
    # each decode step t produces logits for position t
    for i, t in enumerate(range(S // 2, S)):
        np.testing.assert_allclose(
            np.asarray(step_logits[i + 1 - 1] if False else step_logits[i + 1]),
            np.asarray(full[:, t]), rtol=5e-3, atol=5e-3,
            err_msg=f"{name} decode pos {t}")


def test_param_count_close_to_estimate():
    """Analytic 6ND param estimate tracks the real init within 5%."""
    for name in ("granite-3-8b", "olmoe-1b-7b", "xlstm-350m"):
        cfg = reduced(get_config(name), d_model=64)
        params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
        real = param_count(params)
        est = cfg.param_count_estimate()
        assert abs(real - est) / real < 0.05, (name, real, est)
