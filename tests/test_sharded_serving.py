"""Tensor-parallel sharded serving + the unified ServeConfig surface.

Mesh-dependent cases (divisibility across host-mesh widths, sharded-vs-
single bit-identity) spawn a subprocess with forced host devices so this
file doesn't poison the single-device backend state of the rest of the
suite (the tests/test_sharding.py discipline). Router and ServeConfig
cases run in-process on the normal single-device backend.
"""
import dataclasses
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src, *argv, timeout=900):
    r = subprocess.run([sys.executable, "-c", src, *argv],
                       capture_output=True, text=True, timeout=timeout,
                       cwd=REPO)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-4000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# ShardingPolicy divisibility on 2/4/8-wide serving meshes
# ---------------------------------------------------------------------------

_DIV_WORKER = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.configs import assigned_archs, get_config
from repro.launch.mesh import make_serving_mesh
from repro.launch.steps import _params_sds
from repro.sharding import ShardingPolicy

class Leaf:            # shape-only stand-in for a pool array
    def __init__(self, shape): self.shape = shape

def check_specs(specs, tree, sizes, where):
    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    flat_x, _ = jax.tree_util.tree_flatten(tree)
    assert len(flat_s) == len(flat_x), where
    for spec, leaf in zip(flat_s, flat_x):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= sizes[a]
            assert dim % n == 0, (where, leaf.shape, spec)

for width in (2, 4, 8):
    mesh = make_serving_mesh(model=width,
                             devices=jax.devices()[:width])
    sizes = dict(mesh.shape)
    for arch in assigned_archs():
        cfg = get_config(arch)
        policy = ShardingPolicy(cfg, mesh, fsdp=False, parallelism="tp")
        sds = _params_sds(cfg, jnp.bfloat16, quantized=False)
        check_specs(policy.param_specs(sds), sds, sizes,
                    (arch, width, "params"))
        # paged pools shaped like the engine's state cache: plain KV,
        # quantized codes+scale, a cross entry and a recurrent slab
        Hkv, dh = cfg.n_kv_heads, cfg.dh
        caches = {"l0": {"kp": Leaf((2, 8, Hkv, 8, dh)),
                         "vp": Leaf((2, 8, Hkv, 8, dh)),
                         "slab": Leaf((2, 4, dh))},
                  "l1": {"kp": {"codes": Leaf((2, 8, Hkv, 8, dh)),
                                "scale": Leaf((2, 8, Hkv, 8, 1))},
                         "vp": {"codes": Leaf((2, 8, Hkv, 8, dh)),
                                "scale": Leaf((2, 8, Hkv, 8, 1))}},
                  "xk": Leaf((2, 2, Hkv, 16, dh))}
        specs = policy.paged_state_specs(caches)
        check_specs(specs, caches, sizes, (arch, width, "pools"))
        # the head axis shards exactly when the width divides it; slabs
        # and scale head-axes follow the same rule, never unevenly
        want = ("model" if Hkv % width == 0 else None)
        assert tuple(specs["l0"]["kp"])[2] == want, (arch, width)
        assert tuple(specs["l0"]["slab"]) == (None, None, None), arch
print("OK divisible")
"""


def test_policy_divisible_across_serving_mesh_widths():
    """Every bundled config gets divisible (or replicated) specs for
    params AND paged pools on 2/4/8-wide model meshes — jit inputs
    cannot shard unevenly."""
    assert "OK divisible" in _run(_DIV_WORKER)


# ---------------------------------------------------------------------------
# Sharded-vs-single bit-identity on the pinned greedy workload
# ---------------------------------------------------------------------------

_IDENTITY_WORKER = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")
import dataclasses
import jax
import numpy as np
from repro.configs import get_config, reduced
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving import ServeConfig, ServeEngine
from repro.serving.engine import Request

kvq = sys.argv[1] == "kvq"
spec = sys.argv[2] == "spec"
fused = sys.argv[3] == "fused"

# the pinned exact-greedy workload (vocab 32 keeps random-init top-2
# logit gaps wide; dh=128 keeps kernels in their deployed regime) with
# n_kv_heads=2 so a 2-wide model axis has a head each
cfg = dataclasses.replace(reduced(get_config("gemma-2b"), vocab=32),
                          head_dim=128, n_kv_heads=2)
params = lm_mod.lm_init(jax.random.PRNGKey(3), cfg)
rt = Runtime(impl="ref", q_chunk=16, kv_quant=kvq,
             kv_scheme="spx_8_x3" if kvq else "none")
rng = np.random.default_rng(3)
prompts = [rng.integers(1, cfg.vocab_size, int(n)).astype(np.int32)
           for n in (3, 9, 17, 6)]

def drive(shards):
    sc = ServeConfig(batch_slots=2, max_seq=64, quantize="sp2_4",
                     kv_layout="paged", page_size=8,
                     spec_decode=spec, spec_k=2 if spec else None,
                     fused_decode=fused, shards=shards)
    eng = ServeEngine(params, cfg, sc, rt=rt)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    out = {r.rid: tuple(r.output) for r in eng.run()}
    return out, eng.metrics()

base, m1 = drive(1)
shrd, m2 = drive(2)
assert base == shrd, (base, shrd)
assert m2["shards"] == 2 and m2["kv_sharded"] is True
assert m2["kv_heads_per_shard"] == 1
# head-sharding halves the per-shard KV bytes
assert m2["peak_kv_bytes_per_shard"] * 2 == m2["peak_kv_bytes"], m2
assert m1["peak_kv_bytes_per_shard"] == m1["peak_kv_bytes"]
print("OK identical", m2["peak_kv_bytes_per_shard"])
"""


@pytest.mark.parametrize("kvq", [False, True], ids=["plain", "spx-kv"])
@pytest.mark.parametrize("spec", [False, True], ids=["nospec", "spec"])
@pytest.mark.parametrize("fused", [False, True], ids=["unfused", "fused"])
def test_sharded_greedy_bit_identical(kvq, spec, fused):
    """shards=2 on a forced-host mesh reproduces the single-device
    greedy outputs bit-for-bit, with per-shard KV bytes halved."""
    out = _run(_IDENTITY_WORKER, "kvq" if kvq else "plain",
               "spec" if spec else "nospec",
               "fused" if fused else "unfused")
    assert "OK identical" in out


# ---------------------------------------------------------------------------
# Replica router (in-process: single device, shards=1)
# ---------------------------------------------------------------------------

import jax  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models import lm as lm_mod  # noqa: E402
from repro.runtime import Runtime  # noqa: E402
from repro.serving import ReplicaRouter, ServeConfig, ServeEngine  # noqa: E402
from repro.serving.engine import Request  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

RT = Runtime(impl="ref", q_chunk=16)
CFG = dataclasses.replace(reduced(get_config("gemma-2b"), vocab=32),
                          head_dim=128)


@pytest.fixture(scope="module")
def params():
    return lm_mod.lm_init(jax.random.PRNGKey(3), CFG)


def _reqs(n=8, seed=3, new_tokens=4):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, CFG.vocab_size,
                                        int(rng.integers(3, 12)))
                    .astype(np.int32),
                    max_new_tokens=new_tokens)
            for i in range(n)]


def test_router_distributes_wave_and_merges_metrics(params):
    """8 identical-load requests over 2 replicas land 4/4 (least-loaded
    with deterministic ties), outputs match a single engine, and the
    fleet metrics sum counters / recompute percentiles."""
    sc = ServeConfig(batch_slots=2, max_seq=64, quantize=None,
                     kv_layout="paged", page_size=8, replicas=2)
    router = ReplicaRouter(params, CFG, sc, rt=RT)
    placements = [router.submit(r) for r in _reqs()]
    assert placements == [0, 1, 0, 1, 0, 1, 0, 1]
    done = router.run()
    assert sorted(r.rid for r in done) == list(range(8))

    solo = ServeEngine(params, CFG,
                       ServeConfig(batch_slots=2, max_seq=64, quantize=None,
                                   kv_layout="paged", page_size=8), rt=RT)
    for r in _reqs():
        solo.submit(r)
    want = {r.rid: tuple(r.output) for r in solo.run()}
    assert {r.rid: tuple(r.output) for r in done} == want

    m = router.metrics()
    assert m["replicas"] == 2 and m["requests_per_replica"] == [4, 4]
    assert m["requests_finished"] == 8
    assert m["tokens_generated"] == sum(len(o) for o in want.values())
    per = m["per_replica"]
    assert len(per) == 2
    assert m["engine_steps"] == sum(p["engine_steps"] for p in per)
    assert m["peak_kv_bytes"] == sum(p["peak_kv_bytes"] for p in per)
    # percentiles recomputed over the union, not averaged
    assert m["ttft_p50_ms"] > 0 and m["latency_p95_ms"] > 0


def test_router_routes_streams_and_rejects_duplicate_rids(params):
    sc = ServeConfig(batch_slots=2, max_seq=64, quantize=None,
                     kv_layout="paged", page_size=8, replicas=2)
    router = ReplicaRouter(params, CFG, sc, rt=RT)
    reqs = _reqs(4)
    for r in reqs:
        router.submit(r)
    with pytest.raises(ValueError, match="already routed"):
        router.submit(Request(rid=0, prompt=reqs[0].prompt,
                              max_new_tokens=2))
    with pytest.raises(KeyError, match="never routed"):
        router.stream(99)
    assert router.cancel(1) is True
    done = router.run()
    assert sorted(r.rid for r in done) == [0, 2, 3]
    assert router.metrics()["requests_cancelled"] == 1


def test_engine_rejects_router_knob(params):
    with pytest.raises(ValueError, match="ReplicaRouter"):
        ServeEngine(params, CFG,
                    ServeConfig(quantize=None, kv_layout="paged",
                                replicas=2), rt=RT)


# ---------------------------------------------------------------------------
# ServeConfig: resolution ownership, validation, one-PR legacy shim
# ---------------------------------------------------------------------------

def test_resolve_fills_every_knob_and_is_idempotent():
    sc = ServeConfig(quantize=None).resolve(CFG)
    assert sc.resolved
    assert sc.kv_layout == "paged"           # auto -> paged
    assert sc.prefill_chunk == 32
    assert sc.scheduler == "cb"
    assert sc.fused_decode is True
    assert sc.spec_decode is False and sc.spec_k == 0
    assert sc.shards == 1 and sc.replicas == 1
    assert sc.resolve(CFG) is sc             # idempotent
    # replace() invalidates; re-resolving the off pair stays off
    again = sc.replace(batch_slots=8).resolve(CFG)
    assert again.spec_k == 0 and not sc.replace(batch_slots=8).resolved


def test_resolve_owns_env_fallbacks(monkeypatch):
    """REPRO_* envs are read in resolve() and nowhere else: an already-
    resolved config is immune to env changes."""
    monkeypatch.setenv("REPRO_SHARDS", "4")
    monkeypatch.setenv("REPRO_REPLICAS", "3")
    monkeypatch.setenv("REPRO_SCHEDULER", "fifo")
    sc = ServeConfig(quantize=None).resolve(CFG)
    assert sc.shards == 4 and sc.replicas == 3 and sc.scheduler == "fifo"
    monkeypatch.setenv("REPRO_SHARDS", "2")
    assert sc.resolve(CFG).shards == 4       # resolved: env not re-read
    # dense degrades the env shards silently; explicit shards= raises
    dense = ServeConfig(quantize=None, kv_layout="dense").resolve(CFG)
    assert dense.shards == 1
    with pytest.raises(ValueError, match="paged"):
        ServeConfig(quantize=None, kv_layout="dense",
                    shards=2).resolve(CFG)


def test_resolve_validates_new_knobs():
    with pytest.raises(ValueError, match="shards"):
        ServeConfig(quantize=None, shards=0).resolve(CFG)
    with pytest.raises(ValueError, match="replicas"):
        ServeConfig(quantize=None, replicas=0).resolve(CFG)


def test_legacy_kwargs_warn_once_and_forward(params):
    """The one-PR shim: old-style knob kwargs still build the same
    engine, under a DeprecationWarning naming ServeConfig."""
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        eng = ServeEngine(params, CFG, rt=RT, batch_slots=2, max_seq=64,
                          quantize=None, kv_layout="paged", page_size=8)
    assert eng.config.batch_slots == 2
    assert eng.config.page_size == 8 and eng.config.resolved
    with pytest.raises(TypeError, match="ServeConfig"):
        ServeEngine(params, CFG, rt=RT, quantize=None, bogus_knob=1)
    # new-style construction must stay warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ServeEngine(params, CFG,
                    ServeConfig(batch_slots=2, max_seq=64, quantize=None,
                                kv_layout="paged", page_size=8), rt=RT)
