"""The execution-plan runtime: registry dispatch, block planning, and the
frozen Runtime as a static jit argument (retrace regression)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.core.pipeline import TPU_V5E
from repro.core.quantized import quantize_weight
from repro.kernels import ops
from repro.runtime import (KernelUnavailable, Runtime, planner, registry)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_ops_registered():
    assert set(registry.registered_ops()) >= {"spx_matmul", "flash_attention"}
    for op in ("spx_matmul", "flash_attention"):
        assert set(registry.available_impls(op)) >= {"ref", "interpret"}


def test_registry_auto_resolves_ref_on_cpu():
    for op in ("spx_matmul", "flash_attention"):
        assert registry.resolve(op, "auto").impl == "ref"


def test_registry_explicit_and_unknown():
    assert registry.resolve("spx_matmul", "interpret").impl == "interpret"
    with pytest.raises(KernelUnavailable):
        registry.resolve("spx_matmul", "cuda")
    with pytest.raises(KernelUnavailable):
        registry.resolve("not_an_op", "ref")


def test_registry_resolution_is_cached():
    a = registry.resolve("spx_matmul", "auto")
    b = registry.resolve("spx_matmul", "auto")
    assert a is b


# ---------------------------------------------------------------------------
# Planner: budget + divisibility across the bundled model configs
# ---------------------------------------------------------------------------

def _config_matmul_shapes(cfg):
    """The hot (K, N) weight shapes of one architecture."""
    d, dh = cfg.d_model, cfg.dh
    shapes = [(d, cfg.n_heads * dh), (d, cfg.n_kv_heads * dh),
              (cfg.n_heads * dh, d)]
    if cfg.d_ff:
        shapes += [(d, cfg.d_ff), (cfg.d_ff, d)]
    return shapes


@pytest.mark.parametrize("arch", list_configs())
@pytest.mark.parametrize("bits", [4, 8])
def test_plans_respect_budget_and_divisibility(arch, bits):
    cfg = get_config(arch)
    for m in (8, 256, 4096):
        for k_dim, n_dim in _config_matmul_shapes(cfg):
            plan = planner.plan_matmul(m, k_dim, n_dim, weight_bits=bits,
                                       packed=(bits == 4))
            if plan is None:      # ragged: legal, falls back to ref
                continue
            assert n_dim % plan.bn == 0, (arch, k_dim, n_dim, plan)
            assert k_dim % plan.bk == 0, (arch, k_dim, n_dim, plan)
            if bits == 4:
                assert plan.bn % 2 == 0     # packed int4: even bn
            assert plan.vmem_bytes <= (TPU_V5E.vmem_bytes
                                       * planner.VMEM_BUDGET_FRACTION)


@pytest.mark.parametrize("arch", list_configs())
def test_attention_plans_divisible(arch):
    cfg = get_config(arch)
    if cfg.n_heads == 0:
        pytest.skip("no attention")
    for s in (128, 4096, 32768):
        plan = planner.plan_attention(s, s, cfg.dh)
        assert plan is not None
        assert s % plan.bq == 0 and s % plan.bkv == 0
        assert plan.vmem_bytes <= (TPU_V5E.vmem_bytes
                                   * planner.VMEM_BUDGET_FRACTION)


def test_plan_cache_hits():
    planner.plan_matmul(64, 256, 256, weight_bits=8)
    before = planner._plan_matmul_cached.cache_info().hits
    planner.plan_matmul(64, 256, 256, weight_bits=8)
    assert planner._plan_matmul_cached.cache_info().hits == before + 1


def test_ragged_dims_return_none():
    assert planner.plan_matmul(8, 250, 130, weight_bits=4) is None
    assert planner.plan_attention(7, 13, 64) is None


def test_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_BLOCKS_MATMUL", "64,128,128")
    plan = planner.plan_matmul(256, 256, 256, weight_bits=4)
    assert (plan.bm, plan.bn, plan.bk) == (64, 128, 128)
    monkeypatch.setenv("REPRO_BLOCKS_ATTN", "32,64")
    ap = planner.plan_attention(128, 128, 64)
    assert (ap.bq, ap.bkv) == (32, 64)
    # non-dividing pin -> ref fallback, not a crash
    monkeypatch.setenv("REPRO_BLOCKS_MATMUL", "64,100,100")
    assert planner.plan_matmul(256, 256, 256, weight_bits=4) is None


def test_measured_best_caches_winner():
    planner.clear_plan_cache()
    key = ("spx_matmul", 16, 256, 128, 4, True)
    plans = [planner.MatmulBlocks(128, 128, 128, False, 0.0, 0),
             planner.MatmulBlocks(64, 128, 128, False, 0.0, 0)]
    times = {id(plans[0]): 2.0, id(plans[1]): 1.0}
    best = planner.measured_best(key, plans, lambda p: times[id(p)])
    assert best is plans[1]
    # the winner is visible to later (including trace-time) lookups ...
    assert planner.measured_plan(key) is plans[1]
    # ... and the runner is not re-invoked for a known key
    assert planner.measured_best(key, plans, lambda p: 1 / 0) is plans[1]
    planner.clear_plan_cache()
    assert planner.measured_plan(key) is None


# ---------------------------------------------------------------------------
# Planned dispatch end to end (interpret impl runs the kernel body on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (48, 256, 128),
                                   (200, 384, 256)])
def test_planned_spx_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.05, jnp.float32)
    qt = quantize_weight(w, "sp2_4")
    want = ops.spx_matmul(x, qt, impl="ref")
    got = ops.spx_matmul(x, qt, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_planned_flash_attention_matches_ref():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 4, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 64, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 64, 32)), jnp.float32)
    want = ops.flash_attention(q, k, v, causal=True, impl="ref")
    got = ops.flash_attention(q, k, v, causal=True, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Frozen Runtime: hashability + retrace regression
# ---------------------------------------------------------------------------

def test_runtime_frozen_and_hashable():
    rt = Runtime(impl="ref", q_chunk=16)
    with pytest.raises(Exception):
        rt.impl = "pallas"
    assert rt == rt.replace()
    assert hash(rt) == hash(rt.replace())
    assert rt.replace(q_chunk=32) != rt
    assert isinstance(Runtime(data_axes=["data", "pod"]).data_axes, tuple)


def test_no_retrace_on_equal_runtime():
    """Replacing a Runtime with an equal-valued copy must hit the jit cache
    (zero recompiles) when it rides as a static argument."""
    rt = Runtime(impl="ref", q_chunk=8)
    traces = []

    def f_impl(x, rt):
        traces.append(1)
        return x * rt.q_chunk

    f = jax.jit(f_impl, static_argnums=1)
    x = jnp.ones((4,))
    f(x, rt)
    assert f._cache_size() == 1
    f(x, rt.replace())                      # equal values -> cache hit
    f(x, Runtime(impl="ref", q_chunk=8))    # fresh equal object -> cache hit
    assert f._cache_size() == 1
    assert len(traces) == 1
    f(x, rt.replace(q_chunk=16))            # different value -> one retrace
    assert f._cache_size() == 2


def test_engine_decode_reuses_compilation():
    """End-to-end: the serving engine's static (cfg, rt) jit arguments do
    not retrace across equal-valued Runtime replacements."""
    from repro.configs import reduced
    from repro.models import lm as lm_mod

    cfg = reduced(get_config("gemma-2b"), d_model=64, vocab=128)
    rt = Runtime(impl="ref", q_chunk=16)
    params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
    caches = lm_mod.init_caches(cfg, 1, 16, dtype=jnp.float32)
    step = jax.jit(lm_mod.lm_decode_step, static_argnums=(4, 5))
    tok = jnp.zeros((1,), jnp.int32)
    pos = jnp.zeros((), jnp.int32)
    _, caches = step(params, tok, pos, caches, cfg, rt)
    n = step._cache_size()
    _, caches = step(params, tok, pos + 1, caches, cfg, rt.replace())
    assert step._cache_size() == n
