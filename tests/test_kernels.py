"""Per-kernel validation: pallas (interpret mode) vs pure-jnp ref oracle,
swept over shapes, dtypes, schemes and block sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spx
from repro.core.quantized import quantize_weight
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.spx_matmul import spx_matmul_pallas

jax.config.update("jax_platform_name", "cpu")


def _mk(shape, dtype, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# spx_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(8, 128, 128), (128, 128, 256),
                                   (64, 256, 128), (256, 128, 384)])
@pytest.mark.parametrize("scheme", ["sp2_4", "sp2_8", "spx_8_x3"])
def test_spx_matmul_shapes_schemes(m, n, k, scheme):
    x = _mk((m, k), jnp.float32, seed=m + n + k)
    w = _mk((k, n), jnp.float32, seed=1, scale=0.05)
    qt = quantize_weight(w, scheme)
    scale = qt.scale.reshape(1, n)
    want = ref.spx_matmul_ref(x, qt.codes, scale, qt.lut, packed=qt.packed)
    got = spx_matmul_pallas(x, qt.codes, scale, qt.lut, packed=qt.packed,
                            bm=min(128, m), bn=128, bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spx_matmul_dtypes(dtype):
    x = _mk((64, 256), dtype, seed=7)
    w = _mk((256, 128), jnp.float32, seed=8, scale=0.05)
    qt = quantize_weight(w, "sp2_4")
    scale = qt.scale.reshape(1, 128)
    want = ref.spx_matmul_ref(x, qt.codes, scale, qt.lut, packed=qt.packed)
    got = spx_matmul_pallas(x, qt.codes, scale, qt.lut, packed=qt.packed,
                            bm=64, bn=128, bk=128, interpret=True)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("blocks", [(32, 128, 128), (64, 64, 64),
                                    (128, 128, 384)])
def test_spx_matmul_block_sweep(blocks):
    bm, bn, bk = blocks
    x = _mk((128, 384), jnp.float32, seed=11)
    w = _mk((384, 256), jnp.float32, seed=12, scale=0.05)
    qt = quantize_weight(w, "sp2_8")   # unpacked path
    scale = qt.scale.reshape(1, 256)
    want = ref.spx_matmul_ref(x, qt.codes, scale, qt.lut, packed=qt.packed)
    got = spx_matmul_pallas(x, qt.codes, scale, qt.lut, packed=qt.packed,
                            bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_ops_wrapper_pads_ragged_m_and_reshapes():
    x = _mk((3, 5, 256), jnp.float32, seed=13)   # leading dims + ragged M=15
    w = _mk((256, 128), jnp.float32, seed=14, scale=0.05)
    qt = quantize_weight(w, "sp2_4")
    want = ops.spx_matmul(x, qt, impl="ref")
    got = ops.spx_matmul(x, qt, impl="interpret")
    assert got.shape == (3, 5, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_ops_wrapper_ragged_k_falls_back_to_ref():
    x = _mk((4, 100), jnp.float32, seed=15)      # K=100 has no aligned block
    w = _mk((100, 30), jnp.float32, seed=16)     # N=30 ragged too
    qt = quantize_weight(w, "sp2_4")
    got = ops.spx_matmul(x, qt, impl="interpret")
    want = ops.spx_matmul(x, qt, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_quantized_matmul_end_to_end_snr():
    """The kernel path preserves the quantization SNR of the scheme."""
    x = _mk((32, 512), jnp.float32, seed=17)
    w = _mk((512, 256), jnp.float32, seed=18, scale=0.02)
    qt = quantize_weight(w, "sp2_8")
    exact = x @ w
    got = ops.spx_matmul(x, qt, impl="interpret", out_dtype=jnp.float32)
    snr = 20 * np.log10(np.linalg.norm(exact) /
                        np.linalg.norm(np.asarray(got) - np.asarray(exact)))
    assert snr > 25.0


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,skv,dh", [(128, 128, 64), (256, 256, 128),
                                       (128, 384, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(sq, skv, dh, causal):
    if causal and sq != skv:
        pytest.skip("causal requires square for this oracle comparison")
    bh = 3
    q = _mk((bh, sq, dh), jnp.float32, seed=21)
    k = _mk((bh, skv, dh), jnp.float32, seed=22)
    v = _mk((bh, skv, dh), jnp.float32, seed=23)
    want = ref.attention_ref(q, k, v, causal=causal)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=64, bkv=128,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = _mk((2, 128, 64), dtype, seed=31)
    k = _mk((2, 128, 64), dtype, seed=32)
    v = _mk((2, 128, 64), dtype, seed=33)
    want = ref.attention_ref(q, k, v, causal=True)
    got = flash_attention_pallas(q, k, v, causal=True, bq=64, bkv=64,
                                 interpret=True)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_block_sweep():
    q = _mk((2, 256, 64), jnp.float32, seed=41)
    k = _mk((2, 256, 64), jnp.float32, seed=42)
    v = _mk((2, 256, 64), jnp.float32, seed=43)
    want = ref.attention_ref(q, k, v, causal=True)
    for bq, bkv in [(32, 32), (64, 128), (256, 64), (128, 256)]:
        got = flash_attention_pallas(q, k, v, causal=True, bq=bq, bkv=bkv,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3, err_msg=f"{bq},{bkv}")


def test_gqa_wrapper_expansion():
    """ops.flash_attention handles GQA (Hq=8, Hkv=2) and matches per-group ref."""
    b, hq, hkv, s, dh = 2, 8, 2, 128, 64
    q = _mk((b, hq, s, dh), jnp.float32, seed=51)
    k = _mk((b, hkv, s, dh), jnp.float32, seed=52)
    v = _mk((b, hkv, s, dh), jnp.float32, seed=53)
    got = ops.flash_attention(q, k, v, causal=True, impl="interpret")
    kr = jnp.repeat(k, hq // hkv, axis=1).reshape(b * hq, s, dh)
    vr = jnp.repeat(v, hq // hkv, axis=1).reshape(b * hq, s, dh)
    want = ref.attention_ref(q.reshape(b * hq, s, dh), kr, vr,
                             causal=True).reshape(q.shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("ps", [8, 16])
def test_paged_attention_vs_ref(hq, hkv, ps):
    """Interpret-mode kernel (scalar-prefetched block table, online softmax
    over pages) vs the gather-everything ref oracle, across GQA ratios,
    page sizes, partial last pages and inactive (ctx=0) rows."""
    rng = np.random.default_rng(hq * 100 + hkv * 10 + ps)
    B, dh, n_pages, max_pages = 3, 32, 12, 3
    q = _mk((B, hq, dh), jnp.float32, seed=ps + hq)
    kp = _mk((n_pages, hkv, ps, dh), jnp.float32, seed=2)
    vp = _mk((n_pages, hkv, ps, dh), jnp.float32, seed=3)
    bt = jnp.asarray(rng.permutation(n_pages)[:B * max_pages]
                     .reshape(B, max_pages), jnp.int32)
    ctx = jnp.asarray([ps + 3, max_pages * ps, 0], jnp.int32)
    want = ops.paged_attention(q, kp, vp, bt, ctx, impl="ref")
    got = ops.paged_attention(q, kp, vp, bt, ctx, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(got[2]))) == 0.0     # inactive row


def test_paged_kv_write_scatter_and_masking():
    """paged_kv_write places token (b, i) at (block_table[b, p//ps],
    p % ps) and drops invalid rows instead of writing them."""
    from repro.nn.attention import paged_kv_write
    ps, n_pages, hkv, dh = 4, 6, 2, 8
    kp = jnp.zeros((n_pages, hkv, ps, dh), jnp.float32)
    vp = jnp.zeros_like(kp)
    k_new = _mk((1, 3, hkv, dh), jnp.float32, seed=4)
    v_new = _mk((1, 3, hkv, dh), jnp.float32, seed=5)
    bt = jnp.asarray([[5, 2, 0]], jnp.int32)
    pos = jnp.asarray([[3, 4, 5]], jnp.int32)     # page 0 last slot, page 1
    valid = jnp.asarray([[True, True, False]])    # third token masked
    kp2, vp2 = paged_kv_write(kp, vp, k_new, v_new, bt, pos, valid)
    np.testing.assert_allclose(np.asarray(kp2[5, :, 3]),
                               np.asarray(k_new[0, 0]))
    np.testing.assert_allclose(np.asarray(vp2[2, :, 0]),
                               np.asarray(v_new[0, 1]))
    assert float(jnp.abs(kp2[2, :, 1]).max()) == 0.0   # dropped write
