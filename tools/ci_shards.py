"""Single source of truth for the tier-1 CI test shards.

The CI workflow runs the tier-1 suite as three parallel shards. Sharding
is by ``--ignore`` lists rather than explicit file arguments, so pytest
still collects the ``tests/`` directory in every shard — ``conftest.py``'s
``collect_ignore`` (hypothesis-less environments) keeps working, and a
test file missing from every shard's map *runs everywhere* rather than
silently nowhere. This module owns the shard → test-file map; the
workflow derives each shard's pytest arguments from it and the ``checks``
job asserts the map is disjoint and exhaustive, so adding a test file
without assigning it here fails CI fast.

  python tools/ci_shards.py --check              # disjoint + exhaustive?
  python tools/ci_shards.py --ignore-args core   # pytest args for a shard
  python tools/ci_shards.py --list               # shard names

Keep shards time-balanced (each CI shard has a 30-minute budget;
``--durations=15`` in the workflow log shows the slowest tests per
shard) — rebalance by moving files between lists, nothing else to edit.
"""
from __future__ import annotations

import argparse
import glob
import os
import sys

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

#: shard name -> test files it runs (paths relative to the repo root).
#: Every tests/test_*.py must appear in exactly one list (--check).
SHARDS: dict[str, list[str]] = {
    # kernels/runtime/quant math/docs — many small fast tests
    "core": [
        "tests/test_attention.py",
        "tests/test_ci_shards.py",
        "tests/test_docs.py",
        "tests/test_kernels.py",
        "tests/test_moe.py",
        "tests/test_runtime.py",
        "tests/test_spx_quant.py",
        "tests/test_ssm.py",
    ],
    # serving engine + model-level serving paths
    "serving-models": [
        "tests/test_fused_decode.py",
        "tests/test_kv_quant.py",
        "tests/test_models_smoke.py",
        "tests/test_prefix_cache.py",
        "tests/test_scheduler.py",
        "tests/test_serving.py",
        "tests/test_spec_decode.py",
        "tests/test_state_cache.py",
        "tests/test_streaming.py",
    ],
    # multi-device dry-runs + training loops — few long tests
    "system-training": [
        "tests/test_sharding.py",
        "tests/test_system.py",
        "tests/test_training.py",
    ],
    # tensor-parallel serving: runs under forced host devices
    # (XLA_FLAGS=--xla_force_host_platform_device_count=8 in CI)
    "sharded": [
        "tests/test_sharded_serving.py",
    ],
}


def discovered_test_files(repo: str = REPO) -> list[str]:
    """The tier-1 test files on disk (what pytest would collect from)."""
    return sorted(os.path.relpath(p, repo).replace(os.sep, "/")
                  for p in glob.glob(os.path.join(repo, "tests",
                                                  "test_*.py")))


def check(shards: dict[str, list[str]] | None = None,
          test_files: list[str] | None = None) -> list[str]:
    """Failure messages (empty = the map is disjoint and exhaustive).

    ``shards``/``test_files`` default to the real map and the files on
    disk; tests inject broken maps to pin the failure modes.
    """
    shards = SHARDS if shards is None else shards
    test_files = (discovered_test_files() if test_files is None
                  else test_files)
    failures = []
    seen: dict[str, str] = {}
    for name, files in shards.items():
        for f in files:
            if f in seen:
                failures.append(
                    f"{f}: assigned to both '{seen[f]}' and '{name}' — "
                    f"shards must be disjoint")
            seen[f] = name
    on_disk = set(test_files)
    for f in sorted(set(seen) - on_disk):
        failures.append(
            f"{f}: in shard '{seen[f]}' but not on disk — remove the "
            f"stale entry")
    for f in sorted(on_disk - set(seen)):
        failures.append(
            f"{f}: not assigned to any shard — add it to exactly one "
            f"list in tools/ci_shards.py (until then it runs in EVERY "
            f"shard)")
    return failures


def ignore_args(shard: str,
                shards: dict[str, list[str]] | None = None) -> list[str]:
    """``--ignore=<file>`` pytest arguments selecting ``shard``: ignore
    every file the *other* shards own. Files missing from the whole map
    are deliberately not ignored anywhere (they run in every shard until
    ``--check`` makes someone assign them)."""
    shards = SHARDS if shards is None else shards
    if shard not in shards:
        raise KeyError(
            f"unknown shard {shard!r}; have {sorted(shards)}")
    others = sorted(f for name, files in shards.items()
                    if name != shard for f in files)
    return [f"--ignore={f}" for f in others]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--check", action="store_true",
                   help="assert shards are disjoint + exhaustive over "
                        "tests/test_*.py")
    g.add_argument("--ignore-args", metavar="SHARD",
                   help="print the pytest --ignore args for one shard")
    g.add_argument("--list", action="store_true",
                   help="print the shard names")
    args = ap.parse_args(argv)
    if args.list:
        print("\n".join(sorted(SHARDS)))
        return 0
    if args.check:
        failures = check()
        for msg in failures:
            print(f"[ci-shards] FAIL {msg}")
        if not failures:
            n = sum(len(v) for v in SHARDS.values())
            print(f"[ci-shards] OK ({len(SHARDS)} shards, {n} test files)")
        return 1 if failures else 0
    try:
        print(" ".join(ignore_args(args.ignore_args)))
    except KeyError as e:
        print(f"[ci-shards] {e.args[0]}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
