"""Compile (and selectively execute) the fenced python snippets in the
docs, so documentation code can't rot silently.

  PYTHONPATH=src python tools/check_doc_snippets.py [files...]

Default file set: README.md and docs/*.md. Every ` ```python ` block must
``compile()``; blocks whose first line is ``# exec-check`` are executed
too (keep those dependency-light and fast — they run in CI and in
tests/test_docs.py). Exits nonzero listing every failing block.
"""
from __future__ import annotations

import glob
import os
import re
import sys

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def default_files() -> list[str]:
    return ([os.path.join(REPO, "README.md")]
            + sorted(glob.glob(os.path.join(REPO, "docs", "*.md"))))


def check_file(path: str) -> list[str]:
    """Returns a list of failure descriptions (empty = all snippets OK)."""
    failures = []
    text = open(path).read()
    rel = os.path.relpath(path, REPO)
    for i, m in enumerate(_FENCE.finditer(text)):
        src = m.group(1)
        line = text[:m.start()].count("\n") + 2       # first snippet line
        tag = f"{rel}:{line} (snippet {i})"
        try:
            code = compile(src, tag, "exec")
        except SyntaxError as e:
            failures.append(f"{tag}: does not compile: {e}")
            continue
        if src.lstrip().startswith("# exec-check"):
            try:
                exec(code, {"__name__": f"doc_snippet_{i}"})
            except Exception as e:
                failures.append(f"{tag}: exec-check failed: {e!r}")
    return failures


def main(argv=None) -> int:
    files = (argv or sys.argv[1:]) or default_files()
    failures, n_files = [], 0
    for f in files:
        if not os.path.exists(f):
            # a typo'd or deleted path must fail loudly, not let the
            # checker report success while checking nothing
            failures.append(f"{f}: file not found")
            continue
        n_files += 1
        failures.extend(check_file(f))
    for msg in failures:
        print(f"[doc-snippets] FAIL {msg}")
    if not failures:
        print(f"[doc-snippets] OK ({n_files} files)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
