"""Benchmark harness: one function per paper table/figure plus the kernel
microbenchmark, the dense-vs-paged serving comparison (which writes
``BENCH_serving.json`` at the repo root), and the fused-vs-unfused decode
megakernel bench (``BENCH_roofline.json``). Prints
``name,us_per_call,derived`` CSV at the end.

  PYTHONPATH=src python -m benchmarks.run [--skip-roofline-table]
      [--skip-fused-decode-bench]
"""
import argparse
import glob
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def kernel_microbench(csv_rows):
    """spx_matmul: ref vs interpret-mode Pallas (correct-by-construction
    check is in tests; here: bytes-moved accounting, the paper's actual
    win on TPU)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.quantized import quantize_weight
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    m, k, n = 256, 1024, 1024
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.03, jnp.float32)
    print("\n== spx_matmul storage/traffic accounting ==")
    dense_bytes = w.size * 2                      # bf16 weights
    for scheme in ("sp2_8", "sp2_4"):
        qt = quantize_weight(w, scheme)
        qbytes = qt.nbytes_stored()
        f = jax.jit(lambda xx, q: ops.spx_matmul(xx, q, impl="ref"))
        jax.block_until_ready(f(x, qt))
        t0 = time.monotonic()
        for _ in range(10):
            jax.block_until_ready(f(x, qt))
        t = (time.monotonic() - t0) / 10
        print(f"  {scheme:6s}: weight bytes {qbytes/1e3:8.1f}KB "
              f"({dense_bytes/qbytes:.1f}x smaller than bf16), "
              f"{t*1e6:8.0f} us/call (host ref path)")
        csv_rows.append((f"kernel/spx_matmul_{scheme}", t * 1e6,
                         dense_bytes / qbytes))


def plan_report(csv_rows):
    """Execution plans the runtime picks for each bundled config's hot
    matmul (d_model -> d_ff at serving batch 256): block geometry from the
    §3.1 analytical model, with the pipeline margin the paper argues in
    prose. These are the tiles the Pallas kernels actually run with."""
    from repro.configs import get_config, list_configs
    from repro.core.pipeline import TPU_V5E
    from repro.runtime import planner

    print("\n== execution plans (spx_matmul, 4-bit, m=256) ==")
    print(f"  {'arch':22s} {'K->N':>14s}  bm x bn x bk   margin  vmem(MB)")
    for name in list_configs():
        cfg = get_config(name)
        k_dim, n_dim = cfg.d_model, cfg.d_ff or cfg.d_model
        plan = planner.plan_matmul(256, k_dim, n_dim, weight_bits=4,
                                   packed=True)
        if plan is None:
            print(f"  {name:22s} {k_dim:6d}->{n_dim:<6d}  (ref fallback: "
                  "ragged dims)")
            continue
        print(f"  {name:22s} {k_dim:6d}->{n_dim:<6d}  "
              f"{plan.bm:4d}x{plan.bn:4d}x{plan.bk:4d} "
              f"{plan.margin:7.2f}  {plan.vmem_bytes/2**20:7.2f}")
        assert plan.vmem_bytes <= TPU_V5E.vmem_bytes
        csv_rows.append((f"plan/{name}", 0.0, plan.margin))


def fused_decode_table(csv_rows):
    """Run the fused-vs-unfused decode bench (benchmarks.roofline
    --fused-decode-bench) in a subprocess — importing benchmarks.roofline
    here would leak roofline-mode environment setup into this process —
    and fold BENCH_roofline.json into the CSV."""
    import subprocess
    repo = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
    subprocess.run([sys.executable, "-m", "benchmarks.roofline",
                    "--fused-decode-bench"], cwd=repo, check=True)
    with open(os.path.join(repo, "BENCH_roofline.json")) as fh:
        r = json.load(fh)
    for axis in ("paged", "paged-spx"):
        csv_rows.append((f"roofline/fused_decode_{axis}_tok_per_s", 0.0,
                         r[axis]["fused"]["tokens_per_s"]))
        csv_rows.append((f"roofline/fused_decode_{axis}_speedup", 0.0,
                         r[axis]["fused_speedup"]))


def roofline_table(csv_rows):
    """Summarize any roofline artifacts present (produced by
    `python -m benchmarks.roofline --all`)."""
    art = os.path.join(os.path.dirname(__file__), "artifacts")
    files = sorted(glob.glob(os.path.join(art, "roofline_*.json")))
    if not files:
        print("\n(no roofline artifacts yet — run benchmarks.roofline)")
        return
    print("\n== roofline summary (see EXPERIMENTS.md §Roofline) ==")
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        if r.get("status") != "ok":
            continue
        csv_rows.append((f"roofline/{r['arch']}/{r['shape']}"
                         + ("_dense" if not r.get("quantized_serving", True)
                            else ""),
                         r["bound_s"] * 1e6, r["roofline_fraction"]))
        print(f"  {r['arch']:22s} {r['shape']:12s} "
              f"{'q' if r.get('quantized_serving', True) else 'd'} "
              f"dom={r['dominant']:10s} bound={r['bound_s']*1e3:9.2f}ms "
              f"frac={r['roofline_fraction']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline-table", action="store_true")
    ap.add_argument("--skip-fused-decode-bench", action="store_true")
    args = ap.parse_args()

    csv_rows: list = []
    from benchmarks import fig5, quant_quality, serving_bench, table1
    table1.run(csv_rows)
    quant_quality.run(csv_rows)
    fig5.run(csv_rows)
    kernel_microbench(csv_rows)
    plan_report(csv_rows)
    serving_bench.run(csv_rows)
    if not args.skip_fused_decode_bench:
        fused_decode_table(csv_rows)
    if not args.skip_roofline_table:
        roofline_table(csv_rows)

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.4f},{derived:.4f}")


if __name__ == '__main__':
    main()
