"""Paper Table 1 analog: inference time-per-sample and energy across
'devices' for the 784-128-10 MLP.

The paper measured CPU (2.6 ms/sample, 47.2 W), GPU (0.3 ms, 115.2 W) and
their FPGA (1.6 us, 10 W). Here:
  * CPU rows are MEASURED on this host (fp32 dense and SPx-quantized paths);
  * the TPU-v5e rows are MODELED from the roofline terms of the same matmul
    sequence (documented formula, batch-1 latency-bound and batched
    throughput-bound), standing in for the paper's accelerator row;
  * energy = device power x time (CPU power from a 65W-class desktop part;
    v5e ~170W) — same methodology as the paper's wattmeter column.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import TPU_V5E
from repro.data.mnist import make_dataset
from repro.models.mlp_mnist import PAPER_LAYERS, paper_mlp_apply, \
    paper_mlp_init
from repro.nn.layers import quantize_params
from repro.runtime import Runtime

CPU_W = 65.0
TPU_W = 170.0


def _measure(fn, *args, iters=30):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / iters


def modeled_tpu_time(batch: int, weight_bits: int) -> float:
    """Latency model for one MLP forward on one v5e chip: per layer
    max(compute, weight+act HBM traffic) + fixed dispatch overhead."""
    t = 2e-6 * len(PAPER_LAYERS)              # dispatch/launch overhead
    for din, dout in zip(PAPER_LAYERS[:-1], PAPER_LAYERS[1:]):
        flops = 2.0 * batch * din * dout
        w_bytes = din * dout * weight_bits / 8
        a_bytes = batch * (din + dout) * 2
        t += max(flops / TPU_V5E.peak_bf16_flops,
                 (w_bytes + a_bytes) / TPU_V5E.hbm_bw)
    return t


def run(csv_rows: list):
    x, _ = make_dataset(1024, seed=7)
    params = paper_mlp_init(jax.random.PRNGKey(0))
    xj = jnp.asarray(x)

    fp = jax.jit(lambda p, xx: paper_mlp_apply(p, xx))
    t_fp = _measure(fp, params, xj) / len(x)

    rtq = Runtime(impl="auto")
    qp = quantize_params(params, "sp2_4", min_size=1024)
    q = jax.jit(lambda p, xx: paper_mlp_apply(p, xx, rtq))
    t_q = _measure(q, qp, xj) / len(x)

    t_tpu_b1 = modeled_tpu_time(1, 16)
    t_tpu_b1_q = modeled_tpu_time(1, 4)
    t_tpu_b1024 = modeled_tpu_time(1024, 4) / 1024

    rows = [
        ("cpu_fp32_measured", t_fp, CPU_W * t_fp),
        ("cpu_sp2_4_measured", t_q, CPU_W * t_q),
        ("tpu_v5e_bf16_modeled_b1", t_tpu_b1, TPU_W * t_tpu_b1),
        ("tpu_v5e_sp2_4_modeled_b1", t_tpu_b1_q, TPU_W * t_tpu_b1_q),
        ("tpu_v5e_sp2_4_modeled_b1024", t_tpu_b1024, TPU_W * t_tpu_b1024),
        ("paper_cpu", 2.6e-3, 47.2 * 2.6e-3),
        ("paper_gpu", 3e-4, 115.2 * 3e-4),
        ("paper_fpga", 1.6e-6, 10.0 * 1.6e-6),
    ]
    print("\n== Table 1 analog: time/sample + energy/sample ==")
    for name, t, e in rows:
        print(f"  {name:28s} {t*1e6:10.2f} us/sample {e*1e6:10.3f} uJ")
        csv_rows.append((f"table1/{name}", t * 1e6, e * 1e6))
    return rows
