"""Target hardware constants (TPU v5e) used by the roofline analysis."""

PEAK_BF16_FLOPS = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per-chip effective here)
HBM_BYTES = 16e9              # per chip
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
