"""Quantization-quality table (paper §3.2 claims, made quantitative):
  * per-scheme weight-quantization SNR on Gaussian + heavy-tailed weights
    (PoT collapses at the tails; SP2/SPx recover — Eq. 3.3/3.4's point);
  * end-task accuracy of the trained paper MLP under each scheme;
  * tail-region level density per scheme.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spx
from repro.core.quantized import dequantize, quantize_weight
from repro.data.mnist import SynthDigits
from repro.models.mlp_mnist import paper_mlp_init, paper_mlp_loss, \
    paper_mlp_predict
from repro.nn.layers import quantize_params
from repro.training import make_optimizer

SCHEMES = ("uniform4", "pot4", "sp2_4", "uniform8", "sp2_8", "spx_8_x3")


def weight_snr(scheme: str, w: jnp.ndarray) -> float:
    qt = quantize_weight(w, scheme, pack=False)
    wh = dequantize(qt, jnp.float32)
    err = jnp.linalg.norm(wh - w)
    return float(20 * jnp.log10(jnp.linalg.norm(w) / (err + 1e-12)))


def _train_mlp(steps=400):
    data = SynthDigits(n_train=4096, n_test=1024, batch_size=64)
    params = paper_mlp_init(jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", lr=0.5)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(paper_mlp_loss)(params, x, y)
        return *opt.update(params, grads, state), loss

    it = data.batches(epochs=100)
    for _ in range(steps):
        x, y = next(it)
        params, state, _ = step(params, state, jnp.asarray(x),
                                jnp.asarray(y))
    return params, data


def run(csv_rows: list):
    rng = np.random.default_rng(0)
    gauss = jnp.asarray(rng.standard_normal((256, 256)) * 0.04, jnp.float32)
    heavy = jnp.asarray(rng.standard_t(3, (256, 256)) * 0.04, jnp.float32)

    print("\n== quantization quality (weight SNR dB / tail density / "
          "MLP accuracy) ==")
    params, data = _train_mlp()
    base_acc = float(jnp.mean(
        (paper_mlp_predict(params, jnp.asarray(data.x_test))
         == jnp.asarray(data.y_test)).astype(jnp.float32)))
    print(f"  float32: MLP acc {base_acc:.3f}")
    csv_rows.append(("quant/float32_acc", base_acc, 0.0))

    for scheme in SCHEMES:
        lv = spx.scheme_levels(scheme)
        width = spx.code_width(lv)
        tail = float(np.sum((lv >= 0.5) & (lv <= 1.0)) / len(lv))
        snr_g = weight_snr(scheme, gauss)
        snr_h = weight_snr(scheme, heavy)
        qp = quantize_params(params, scheme, min_size=1024)
        acc = float(jnp.mean(
            (paper_mlp_predict(qp, jnp.asarray(data.x_test))
             == jnp.asarray(data.y_test)).astype(jnp.float32)))
        print(f"  {scheme:10s} ({width}b): snr_gauss {snr_g:6.2f}dB "
              f"snr_heavy {snr_h:6.2f}dB tail {tail:.3f} acc {acc:.3f} "
              f"(d {acc - base_acc:+.3f})")
        csv_rows.append((f"quant/{scheme}_snr_gauss", snr_g, tail))
        csv_rows.append((f"quant/{scheme}_acc", acc, acc - base_acc))
    return csv_rows
