"""Serving benchmark over KV-memory axes: same weights, same mixed-length
request batch, three cache configurations —

  dense-f32   per-slot (B, Hkv, max_seq, dh) f32 cache (the baseline)
  paged-bf16  block-table page pool, bf16 values
  paged-spx   block-table page pool, SPx-quantized codes + per-token scale
              (non-uniform 8-bit levels, fused-dequant decode kernel)

— reporting throughput, latency percentiles, page occupancy and peak KV
bytes, and checking greedy-output agreement against dense-f32 (paging is a
memory-layout change and 8-bit SPx KV must preserve greedy outputs on this
workload; both are asserted on the ref backend).

A second scenario drives a batch of requests sharing a page-aligned
system prompt through the paged engine with the prefix cache off vs on,
asserting identical greedy outputs, prefill-tokens-skipped > 0, and a
strictly lower peak page count with sharing — the acceptance criteria for
shared-prefix KV page reuse (docs/SERVING.md).

A third scenario drives a repetition-heavy workload (tiled-motif prompts,
the pattern prompt-lookup drafting feeds on) with speculative decoding
off vs on, asserting bit-identical greedy outputs, strictly fewer model
calls, and draft acceptance > 0 — both plain paged and paged+SPx-KV
(docs/SERVING.md, speculative decoding).

A fourth scenario replays a bursty oversubscribed arrival process —
low-priority background requests that fill the page pool, then a
high-priority burst mid-run — through the synchronous FIFO scheduler and
the continuous-batching scheduler on the SAME pool geometry, plain and
SPx-quantized KV. Asserted: the cb engine preempts (preemptions > 0,
offload_bytes == onload_bytes > 0, prefix_evictions > 0 under a
1-page prefix-cache budget) while every request's greedy output stays
bit-identical to the FIFO baseline (CPU; reported elsewhere). The
`preemptions` / `offload_bytes` / `prefix_evictions` totals are copied
to the top level of BENCH_serving.json for the CI checks job.

A streaming scenario serves the oversubscribed workload twice on
identical engines — whole-request `run()` vs per-rid token streams
polled by an external tick loop — asserting streamed tokens bit-identical
to `run()` and consumer-side streamed TTFT p50 strictly below the
whole-request latency p50, then cancels half a resubmitted wave mid-run
(pool `validate()` clean, survivors unchanged). The
`streaming.streamed_ttft_p50_ms` / `streaming.ttft_speedup` /
`streaming.requests_cancelled` keys are what the CI checks job asserts.

A fifth scenario is the unified-state-cache architecture matrix: an SSM
(xlstm-350m), a hybrid (jamba-1.5-large-398b), an encoder-decoder
(whisper-small) and an M-RoPE VLM decoder (qwen2-vl-2b), each reduced,
served dense+fifo vs paged+cb. Asserted: greedy outputs bit-identical
per request (CPU), paged peak_state_bytes strictly below dense for the
SSM/hybrid/enc-dec rows, and whisper's shared input frames hitting the
refcounted cross-KV region (cross_hits > 0). Per-arch results land in
BENCH_serving.json["arch_matrix"] for the CI checks job.

Standalone:  PYTHONPATH=src python -m benchmarks.serving_bench
From run.py: writes BENCH_serving.json at the repo root.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

ARTIFACT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..",
                                         "BENCH_serving.json"))

#: the non-uniform scheme the SPx axis runs (x=3 terms, 131 levels, 8-bit
#: codes — the paper's extension; see docs/QUANTIZATION.md)
SPX_SCHEME = "spx_8_x3"


def run(csv_rows, *, requests: int = 10, slots: int = 4, max_seq: int = 64,
        new_tokens: int = 8, seed: int = 3, out_path: str = ARTIFACT) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import lm as lm_mod
    from repro.runtime import Runtime
    from repro.serving.engine import Request, ServeConfig, ServeEngine

    import dataclasses
    # Geometry notes. reduced() shrinks head_dim to 32, where the 4-byte
    # per-token scale would distort the SPx-vs-bf16 byte ratio (2*dh vs
    # dh+4) far below what serving-scale heads see (gemma-2b's real dh is
    # 256); benchmark at dh=128 — still CPU-cheap, ratio representative
    # (1.94x vs 1.97x). vocab=32 keeps the random-init model's top-2 logit
    # gaps wide relative to the ~2% SPx KV error, so the greedy-agreement
    # assertion checks quantization fidelity instead of coin-flip
    # near-ties (a 512-way random softmax is mostly ties at the top).
    cfg = dataclasses.replace(reduced(get_config("gemma-2b"), vocab=32),
                              head_dim=128)
    rt = Runtime(impl="auto", q_chunk=64)
    params = lm_mod.lm_init(jax.random.PRNGKey(seed), cfg)

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, max_seq // 2)))
               .astype(np.int32) for _ in range(requests)]

    # equal page geometry for the two paged axes so the peak-KV comparison
    # is purely bytes-per-token, not fragmentation of differing page sizes
    axes = {
        "dense-f32": dict(kv_layout="dense", rt=rt),
        "paged-bf16": dict(kv_layout="paged", rt=rt,
                           kv_cache_dtype=jnp.bfloat16, page_size=16),
        "paged-spx": dict(kv_layout="paged", page_size=16,
                          rt=rt.replace(kv_quant=True,
                                        kv_scheme=SPX_SCHEME)),
    }

    outputs = {}
    result = {"config": {"arch": cfg.name, "requests": requests,
                         "batch_slots": slots, "max_seq": max_seq,
                         "new_tokens": new_tokens,
                         "spx_scheme": SPX_SCHEME}}
    print("\n== serving: dense-f32 vs paged-bf16 vs paged-SPx KV ==")
    for axis, kw in axes.items():
        ert = kw.pop("rt")
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=slots, max_seq=max_seq,
                                      quantize="sp2_4", **kw), rt=ert)
        # warmup pass: pay every jit compile (the paged engine compiles
        # O(log prefill_chunk) chunk-width variants vs dense's two steps —
        # timing a cold run would misattribute compile time to the layout)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
        eng.run()
        eng.reset_metrics()
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
        done = eng.run()
        outputs[axis] = {r.rid: r.output for r in done}
        m = eng.metrics()
        result[axis] = m
        print(f"  {axis:10s}: {m['tokens_per_s']:8.1f} tok/s  "
              f"p50 {m['latency_p50_ms']:7.0f}ms  "
              f"p95 {m['latency_p95_ms']:7.0f}ms  "
              f"peak KV {m['peak_kv_bytes'] / 2**10:7.2f} KiB  "
              f"occ {m['occupancy_mean']:.2f}/{m['occupancy_peak']:.2f}")
        csv_rows.append((f"serving/{axis}_tok_per_s", 0.0,
                         m["tokens_per_s"]))
        csv_rows.append((f"serving/{axis}_peak_kv_kib", 0.0,
                         m["peak_kv_bytes"] / 2**10))

    # greedy agreement vs the dense f32 baseline. On the ref backend, with
    # the DEFAULT pinned workload, the paged-bf16 rounding and the SPx
    # quantization error both preserve every greedy token — asserted, so a
    # regression in the fused-dequant path fails the harness. These are
    # genuinely lossy comparisons (unlike the old paged-f32-vs-dense-f32
    # layout check, which was exact by construction), so a CUSTOM workload
    # only reports: a near-tie top-1 flip there is quantization noise, not
    # a bug. Same on TPU, where the two layouts use different kernels and
    # reduction orders.
    pinned_workload = (requests, slots, max_seq, new_tokens, seed) \
        == (10, 4, 64, 8, 3)
    for axis in ("paged-bf16", "paged-spx"):
        agree = float(np.mean([outputs["dense-f32"][i] == outputs[axis][i]
                               for i in range(requests)]))
        if jax.default_backend() == "cpu" and pinned_workload:
            assert agree == 1.0, \
                f"dense-f32 vs {axis} greedy divergence: {agree}"
        elif agree < 1.0:
            print(f"  WARNING: dense-f32 vs {axis} agreement {agree:.3f} "
                  "< 1.0 (near-tie flips under quantization/reduction "
                  "order — not asserted off the pinned default workload)")
        result[f"greedy_agreement_{axis}"] = agree
        csv_rows.append((f"serving/greedy_agreement_{axis}", 0.0, agree))

    # the memory claim: SPx pages (1-byte codes + f32 scale) undercut the
    # bf16 pages by ~2x at matched geometry — dh/(dh+4)*2 exactly
    ratio_spx = (result["paged-bf16"]["peak_kv_bytes"]
                 / max(result["paged-spx"]["peak_kv_bytes"], 1))
    ratio_dense = (result["dense-f32"]["peak_kv_bytes"]
                   / max(result["paged-spx"]["peak_kv_bytes"], 1))
    result["kv_bytes_ratio_bf16_over_spx"] = ratio_spx
    result["kv_bytes_ratio_dense_over_spx"] = ratio_dense
    print(f"  peak-KV ratios: paged-bf16/paged-spx {ratio_spx:.2f}x, "
          f"dense-f32/paged-spx {ratio_dense:.2f}x")
    csv_rows.append(("serving/kv_ratio_bf16_over_spx", 0.0, ratio_spx))

    result["streaming"] = _streaming_scenario(csv_rows, params, cfg, rt)
    result["prefix_cache"] = _prefix_cache_scenario(csv_rows, params, cfg,
                                                    rt)
    result["spec_decode"] = _spec_decode_scenario(csv_rows, params, cfg,
                                                  rt)
    bursty = _bursty_scenario(csv_rows, params, cfg, rt)
    result["bursty"] = bursty
    # the three scheduler headline counters CI asserts on (ISSUE 7):
    # summed across the plain and SPx cb axes of the bursty scenario
    for k in ("preemptions", "offload_bytes", "prefix_evictions"):
        result[k] = bursty[k]
    # unified-state-cache acceptance: every architecture family serves
    # paged (CI asserts the four per-arch keys exist in the artifact)
    result["arch_matrix"] = _arch_matrix_scenario(csv_rows, rt)
    # tensor-parallel acceptance: 1-vs-2-shard tok/s + per-shard peak KV
    # bytes, measured in a forced-8-host-device child process
    result["sharded"] = _sharded_scenario(csv_rows)

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2, sort_keys=True)
    print(f"  wrote {out_path}")
    return result


def _streaming_scenario(csv_rows, params, cfg, rt, *, requests: int = 8,
                        slots: int = 2, max_seq: int = 64,
                        new_tokens: int = 8, seed: int = 3) -> dict:
    """Incremental-delivery scenario: the same oversubscribed workload
    (8 requests through 2 slots) served twice on identical engines —
    once collected whole from ``run()``, once consumed token-by-token
    through per-rid streams while an external loop ticks the engine.
    The streamed pass stamps each request's first *delivered* token
    with a consumer-side monotonic clock, the latency a user actually
    sees; under queueing it lands far below the whole-request latency
    that was the only observable before streaming.

    Asserted (delivery is a read-path change — deterministic on any
    backend): streamed token sequences bit-identical to the ``run()``
    outputs per request; streamed TTFT p50 strictly below the
    whole-request latency p50 of the same pass. A cancellation wave
    rides along: half the requests are cancelled mid-run, the pool's
    ``validate()`` must stay clean and the survivors' outputs stay
    bit-identical."""
    import time

    from repro.serving.engine import Request, ServeConfig, ServeEngine

    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, max_seq // 2)))
               .astype(np.int32) for _ in range(requests)]
    kw = dict(batch_slots=slots, max_seq=max_seq, quantize="sp2_4",
              kv_layout="paged")

    print("\n== serving: whole-request run() vs per-request streams ==")
    # whole-request baseline (warmup pays the compiles, as everywhere)
    base = ServeEngine(params, cfg, ServeConfig(**kw), rt=rt)
    for measured in (False, True):
        for i, p in enumerate(prompts):
            base.submit(Request(rid=i, prompt=p,
                                max_new_tokens=new_tokens))
        done = base.run()
        if not measured:
            base.reset_metrics()
    base_out = {r.rid: r.output for r in done}
    base_m = base.metrics()

    # streamed pass: identical engine, but a delivery loop polls every
    # stream after each tick and timestamps the first delivered token
    eng = ServeEngine(params, cfg, ServeConfig(**kw), rt=rt)
    for i, p in enumerate(prompts):                  # warmup
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
    eng.run()
    eng.reset_metrics()
    reqs = [Request(rid=i, prompt=p, max_new_tokens=new_tokens)
            for i, p in enumerate(prompts)]
    streams, collected, t_first = {}, {}, {}
    for req in reqs:
        eng.submit(req)
        streams[req.rid] = eng.stream(req.rid)
        collected[req.rid] = []
    while eng.has_work():
        eng.step()
        now = time.monotonic()
        for rid, s in streams.items():
            toks = s.poll()
            if toks and rid not in t_first:
                t_first[rid] = now
            collected[rid].extend(toks)
    assert collected == base_out, \
        "streamed tokens diverged from run() outputs"
    m = eng.metrics()
    sttft = sorted(1e3 * (t_first[r.rid] - r.t_enqueue) for r in reqs)
    ttft_p50 = sttft[len(sttft) // 2]
    assert ttft_p50 < m["latency_p50_ms"], \
        (ttft_p50, m["latency_p50_ms"])
    speedup = m["latency_p50_ms"] / max(ttft_p50, 1e-9)
    print(f"  streamed TTFT p50 {ttft_p50:7.1f}ms vs whole-request "
          f"latency p50 {m['latency_p50_ms']:7.1f}ms "
          f"({speedup:.1f}x earlier first token)")

    # cancellation wave: odd rids die after two ticks; the pool must
    # account clean and the survivors must not notice
    eng.reset_metrics()
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
    eng.step()
    eng.step()
    cancelled = [i for i in range(requests) if i % 2]
    for rid in cancelled:
        eng.cancel(rid)
    eng.pool.validate()
    survivors = {r.rid: r.output for r in eng.run()}
    eng.pool.validate()
    cm = eng.metrics()
    assert cm["requests_cancelled"] == len(cancelled), cm
    assert sorted(survivors) == [i for i in range(requests) if not i % 2]
    assert all(survivors[i] == base_out[i] for i in survivors), \
        "cancellation disturbed surviving requests"
    print(f"  cancelled {cm['requests_cancelled']}/{requests} mid-run, "
          f"pool validate clean, survivors bit-identical")

    csv_rows.append(("serving/streamed_ttft_p50_ms", 0.0, ttft_p50))
    csv_rows.append(("serving/streamed_ttft_speedup", 0.0, speedup))
    return {"config": {"requests": requests, "batch_slots": slots,
                       "max_seq": max_seq, "new_tokens": new_tokens},
            "streamed_ttft_p50_ms": ttft_p50,
            "streamed_ttft_p95_ms": sttft[int(0.95 * (len(sttft) - 1))],
            "whole_request_latency_p50_ms": m["latency_p50_ms"],
            "ttft_speedup": speedup,
            "requests_cancelled": cm["requests_cancelled"],
            "run_metrics": base_m, "stream_metrics": m}


def _prefix_cache_scenario(csv_rows, params, cfg, rt, *, requests: int = 8,
                           slots: int = 2, max_seq: int = 64,
                           new_tokens: int = 4, seed: int = 3) -> dict:
    """Shared-system-prompt scenario: every request carries the same
    page-aligned 24-token system prompt (one of them is the *bare* system
    prompt, which exercises the copy-on-write path). Request 0 primes the
    pool alone, then the rest arrive as a wave through ``slots`` batch
    slots — with the prefix cache on, every later request maps the cached
    system-prompt pages instead of re-prefilling them.

    Asserted (acceptance criteria, deterministic on any backend — these
    are scheduling/accounting claims, not numerics): greedy outputs
    identical with sharing on vs off, prefill-tokens-skipped > 0, and
    peak KV pages strictly lower with sharing."""
    from repro.serving.engine import Request, ServeConfig, ServeEngine

    page_size = 8
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, 3 * page_size) \
        .astype(np.int32)
    prompts = [sys_prompt.copy()]                    # primer
    prompts += [np.concatenate(
        [sys_prompt,
         rng.integers(0, cfg.vocab_size,
                      int(rng.integers(1, 6))).astype(np.int32)])
        for _ in range(requests - 2)]
    prompts.append(sys_prompt.copy())                # bare again -> COW

    outputs, mets = {}, {}
    print("\n== serving: shared system prompt, prefix cache off vs on ==")
    for on in (False, True):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=slots, max_seq=max_seq,
                                      quantize="sp2_4", kv_layout="paged",
                                      page_size=page_size, prefix_cache=on),
                          rt=rt)
        eng.submit(Request(rid=0, prompt=prompts[0],
                           max_new_tokens=new_tokens))
        eng.run()                                    # prime the pool
        for i, p in enumerate(prompts[1:], start=1):
            eng.submit(Request(rid=i, prompt=p,
                               max_new_tokens=new_tokens))
        eng.run()
        outputs[on] = {r.rid: r.output for r in eng.finished}
        m = eng.metrics()
        mets[on] = m
        tag = "on " if on else "off"
        print(f"  prefix-cache {tag}: peak {m['peak_kv_pages']:3d} pages  "
              f"hits {m['prefix_hits']}  skipped "
              f"{m['prefill_tokens_skipped']} tok  cow {m['cow_copies']}  "
              f"{m['tokens_per_s']:8.1f} tok/s")

    assert outputs[True] == outputs[False], \
        "prefix cache changed greedy outputs"
    assert mets[False]["prefill_tokens_skipped"] == 0
    assert mets[True]["prefill_tokens_skipped"] > 0, \
        "prefix cache never skipped prefill work"
    assert mets[True]["peak_kv_pages"] < mets[False]["peak_kv_pages"], \
        (mets[True]["peak_kv_pages"], mets[False]["peak_kv_pages"])
    assert mets[True]["cow_copies"] >= 1, "COW path never exercised"

    hit_rate = mets[True]["prefix_hits"] / requests
    csv_rows.append(("serving/prefix_hit_rate", 0.0, hit_rate))
    csv_rows.append(("serving/prefix_tokens_skipped", 0.0,
                     mets[True]["prefill_tokens_skipped"]))
    csv_rows.append(("serving/prefix_peak_pages_ratio", 0.0,
                     mets[True]["peak_kv_pages"]
                     / mets[False]["peak_kv_pages"]))
    return {"config": {"requests": requests, "batch_slots": slots,
                       "page_size": page_size, "system_prompt_tokens":
                       int(len(sys_prompt)), "new_tokens": new_tokens},
            "hit_rate": hit_rate,
            "off": mets[False], "on": mets[True]}


def _spec_decode_scenario(csv_rows, params, cfg, rt, *, requests: int = 6,
                          slots: int = 2, max_seq: int = 64,
                          new_tokens: int = 12, spec_k: int = 4,
                          seed: int = 3) -> dict:
    """Repetition-heavy workload (each prompt tiles a short motif — the
    structure prompt-lookup drafting exploits, and the structure greedy
    decode on small models degenerates into anyway) through the paged
    engine, speculation off vs on, plain and SPx-quantized KV pages.

    Asserted on CPU, where both decode paths are deterministic jnp
    (acceptance criteria for prompt-lookup speculative decoding): greedy
    outputs **bit-identical** with speculation on vs off per KV axis,
    `model_calls` **strictly lower** with speculation, and
    `draft_acceptance_rate` > 0. Off CPU everything is reported, nothing
    asserted: equality compares the C==1 decode kernel against the K+1
    chunk-path verify window (different reduction orders), and the call/
    acceptance claims ride the same argmaxes, so a near-tie flip could
    break the repetition the drafter feeds on."""
    from repro.serving.engine import Request, ServeConfig, ServeEngine

    rng = np.random.default_rng(seed)
    prompts = [np.tile(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                       4) for _ in range(requests)]
    axes = {"paged": rt,
            "paged-spx": rt.replace(kv_quant=True, kv_scheme=SPX_SCHEME)}
    report: dict = {"config": {"requests": requests, "batch_slots": slots,
                               "new_tokens": new_tokens, "spec_k": spec_k}}
    print("\n== serving: speculative decoding off vs on (prompt lookup) ==")
    for axis, ert in axes.items():
        outs, mets = {}, {}
        for spec in (False, True):
            eng = ServeEngine(params, cfg,
                              ServeConfig(batch_slots=slots, max_seq=max_seq,
                                          quantize="sp2_4", kv_layout="paged",
                                          spec_decode=spec,
                                          spec_k=spec_k if spec else None),
                              rt=ert)
            for i, p in enumerate(prompts):        # warmup: pay compiles
                eng.submit(Request(rid=i, prompt=p,
                                   max_new_tokens=new_tokens))
            eng.run()
            eng.reset_metrics()
            for i, p in enumerate(prompts):
                eng.submit(Request(rid=i, prompt=p,
                                   max_new_tokens=new_tokens))
            outs[spec] = {r.rid: r.output for r in eng.run()}
            mets[spec] = eng.metrics()
        on, off = mets[True], mets[False]
        print(f"  {axis:10s}: calls {off['model_calls']:3d} -> "
              f"{on['model_calls']:3d}  accepted/step "
              f"{on['accepted_per_step']:.2f}  acceptance "
              f"{on['draft_acceptance_rate']:.2f}  "
              f"{on['tokens_per_s']:8.1f} tok/s (was "
              f"{off['tokens_per_s']:.1f})")
        import jax
        agree = outs[True] == outs[False]
        if jax.default_backend() == "cpu":
            # acceptance (and so the call saving) rides the target
            # model's argmaxes, which off-CPU can near-tie-flip between
            # the C==1 decode kernel and the K+1 verify window — so all
            # three claims hard-assert only where they are deterministic
            assert agree, f"{axis}: speculation changed greedy outputs"
            assert on["model_calls"] < off["model_calls"], \
                (axis, on["model_calls"], off["model_calls"])
            assert on["draft_acceptance_rate"] > 0, axis
        elif not agree:
            print(f"  WARNING: {axis} spec-on vs spec-off outputs differ "
                  "(near-tie flips across the decode-kernel vs "
                  "verify-window reduction orders — not asserted off "
                  "CPU)")
        report[f"greedy_agreement_{axis}"] = float(agree)
        csv_rows.append((f"serving/spec_{axis}_acceptance", 0.0,
                         on["draft_acceptance_rate"]))
        csv_rows.append((f"serving/spec_{axis}_model_calls_ratio", 0.0,
                         on["model_calls"] / off["model_calls"]))
        report[axis] = {"off": off, "on": on}
    return report


def _bursty_scenario(csv_rows, params, cfg, rt, *, seed: int = 3) -> dict:
    """Bursty oversubscribed arrival process, FIFO vs continuous batching.

    Two priority-0 background requests (4 pages each) fill an 8-page pool
    at tick 0; three priority-5 burst requests (3 pages each) arrive at
    ticks 3-4 with zero free pages, so the cb scheduler must preempt a
    background — offloading its written KV pages to the host tier — and
    resume it after the burst drains. Every prompt shares a 2-page system
    prefix and the cb engine runs the prefix cache under a 1-page budget,
    so finishing requests overflow the cached-free index and force LRU
    evictions. The FIFO engine replays the identical arrival schedule on
    the identical pool.

    Asserted on every backend (scheduling/accounting claims — they depend
    only on request lengths, never on numerics): cb preempts > 0 times,
    offload_bytes == onload_bytes > 0, ends with an empty host tier, and
    evicts > 0 prefix pages; fifo does none of that. Asserted on CPU,
    where greedy argmaxes are deterministic across batch compositions:
    per-request outputs bit-identical fifo vs cb, plain AND SPx-quantized
    pools (the acceptance criterion for the continuous-batching PR)."""
    import jax
    from repro.serving.engine import Request, ServeConfig, ServeEngine

    page_size, slots, pool_pages, max_seq = 8, 2, 8, 48
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab_size, 2 * page_size) \
        .astype(np.int32)
    # (arrival_tick, rid, tail_tokens, new_tokens, priority)
    schedule = [(0, 0, 10, 6, 0), (0, 1, 10, 6, 0),     # background: 4 pg
                (3, 2, 4, 4, 5), (3, 3, 4, 4, 5),       # burst: 3 pg
                (4, 4, 4, 4, 5)]
    tails = {rid: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
             for _, rid, n, _, _ in schedule}

    def drive(eng):
        """Replay the arrival schedule through public step()/run()."""
        pending = sorted(schedule)
        i = 0
        for t in range(len(pending) * 8):
            while i < len(pending) and pending[i][0] <= t:
                _, rid, _, new, pri = pending[i]
                eng.submit(Request(
                    rid=rid,
                    prompt=np.concatenate([sys_prompt, tails[rid]]),
                    max_new_tokens=new, priority=pri))
                i += 1
            if i == len(pending):
                break
            eng.step()
        eng.run(max_steps=400)
        assert eng.drained
        return {r.rid: r.output for r in eng.finished}

    axes = {"paged": rt,
            "paged-spx": rt.replace(kv_quant=True, kv_scheme=SPX_SCHEME)}
    report: dict = {"config": {"schedule": schedule, "page_size": page_size,
                               "batch_slots": slots,
                               "pool_pages": pool_pages,
                               "system_prompt_tokens": int(len(sys_prompt)),
                               "prefix_cache_pages": 1},
                    "preemptions": 0, "offload_bytes": 0,
                    "prefix_evictions": 0}
    print("\n== serving: bursty oversubscription, fifo vs cb scheduler ==")
    for axis, ert in axes.items():
        outs, mets = {}, {}
        for sched in ("fifo", "cb"):
            eng = ServeEngine(params, cfg,
                              ServeConfig(batch_slots=slots, max_seq=max_seq,
                                          quantize="sp2_4",
                                          kv_layout="paged",
                                          page_size=page_size,
                                          pool_pages=pool_pages,
                                          scheduler=sched,
                                          prefix_cache=(sched == "cb"),
                                          prefix_cache_pages=(
                                              1 if sched == "cb" else None)),
                              rt=ert)
            outs[sched] = drive(eng)
            mets[sched] = eng.metrics()
        cb, fifo = mets["cb"], mets["fifo"]
        print(f"  {axis:10s}: preemptions {cb['preemptions']}  "
              f"offload {cb['offload_bytes']} B  "
              f"prefix evictions {cb['prefix_evictions']}  "
              f"(fifo: denials {fifo['admission_denials']})")
        # scheduling claims — deterministic on any backend
        assert cb["preemptions"] > 0, f"{axis}: burst never preempted"
        assert cb["resumes"] > 0, axis
        assert cb["offload_bytes"] == cb["onload_bytes"] > 0, \
            (axis, cb["offload_bytes"], cb["onload_bytes"])
        assert cb["host_pages_in_use"] == 0, \
            f"{axis}: host tier not drained"
        assert cb["prefix_evictions"] > 0, \
            f"{axis}: 1-page cache budget never evicted"
        assert fifo["preemptions"] == fifo["offload_bytes"] == 0
        agree = outs["cb"] == outs["fifo"]
        if jax.default_backend() == "cpu":
            assert agree, f"{axis}: cb scheduler changed greedy outputs"
        elif not agree:
            print(f"  WARNING: {axis} cb vs fifo outputs differ (near-tie "
                  "flips across batch compositions — not asserted off CPU)")
        report[f"greedy_agreement_{axis}"] = float(agree)
        report[axis] = {"fifo": fifo, "cb": cb}
        report["preemptions"] += cb["preemptions"]
        report["offload_bytes"] += cb["offload_bytes"]
        report["prefix_evictions"] += cb["prefix_evictions"]
        csv_rows.append((f"serving/bursty_{axis}_preemptions", 0.0,
                         cb["preemptions"]))
        csv_rows.append((f"serving/bursty_{axis}_offload_kib", 0.0,
                         cb["offload_bytes"] / 2**10))
    return report


def _arch_matrix_scenario(csv_rows, rt, *, slots: int = 4,
                          max_seq: int = 64, new_tokens: int = 8,
                          seed: int = 3) -> dict:
    """Architecture matrix for the unified state cache: one SSM
    (xlstm-350m), one hybrid (jamba-1.5-large-398b), one enc-dec
    (whisper-small) and one M-RoPE VLM decoder (qwen2-vl-2b) — each at
    reduced scale — served dense+fifo vs paged+cb on the same weights
    and requests (3 requests through 4 slots; the whisper requests
    include two sharing identical input frames, so the encoder output
    is computed once and its cross entry refcount-shared).

    Asserted on CPU, where greedy argmaxes are deterministic across
    batch compositions: per-request greedy outputs bit-identical paged
    vs dense for every architecture. Asserted on any backend
    (accounting claims): paged peak_state_bytes strictly below the
    dense baseline for the SSM, hybrid and enc-dec rows — dense bills
    every batch slot's worst case (full-length KV + slab + cross) while
    the state cache bills only live sequences — and whisper records
    cross_hits > 0 for the shared frames. The per-arch keys in
    BENCH_serving.json["arch_matrix"] are what the CI checks job
    asserts on."""
    import jax
    from repro.configs import get_config, reduced
    from repro.models import encdec as encdec_mod
    from repro.models import lm as lm_mod
    from repro.serving.engine import Request, ServeConfig, ServeEngine

    def build(arch):
        if arch == "whisper-small":
            cfg = reduced(get_config("whisper-small"))
            params = encdec_mod.encdec_init(jax.random.PRNGKey(2), cfg)
            fr = np.asarray(jax.random.normal(
                jax.random.PRNGKey(seed),
                (2, cfg.enc_seq_len, cfg.d_model)))
            return cfg, params, [fr[0], fr[0], fr[1]]  # 0 and 1 share
        n_layers = {"xlstm-350m": 4, "jamba-1.5-large-398b": 8,
                    "qwen2-vl-2b": 2}[arch]
        cfg = reduced(get_config(arch), n_layers=n_layers)
        return cfg, lm_mod.lm_init(jax.random.PRNGKey(1), cfg), None

    report: dict = {"config": {"batch_slots": slots, "max_seq": max_seq,
                               "new_tokens": new_tokens, "requests": 3}}
    print("\n== serving: architecture matrix, dense+fifo vs paged+cb ==")
    for arch in ("xlstm-350m", "jamba-1.5-large-398b", "whisper-small",
                 "qwen2-vl-2b"):
        cfg, params, frames = build(arch)
        rng = np.random.default_rng(seed)
        prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
                   for n in (7, 19, 12)]
        outs, mets = {}, {}
        for layout, sched in (("dense", "fifo"), ("paged", "cb")):
            eng = ServeEngine(params, cfg,
                              ServeConfig(batch_slots=slots, max_seq=max_seq,
                                          quantize=None, kv_layout=layout,
                                          scheduler=sched),
                              rt=rt)
            for i, p in enumerate(prompts):
                eng.submit(Request(
                    rid=i, prompt=p, max_new_tokens=new_tokens,
                    frames=None if frames is None else frames[i]))
            eng.run(max_steps=2000)
            assert eng.drained
            outs[layout] = {r.rid: r.output for r in eng.finished}
            mets[layout] = eng.metrics()
        mp, md = mets["paged"], mets["dense"]
        agree = outs["paged"] == outs["dense"]
        if jax.default_backend() == "cpu":
            assert agree, f"{arch}: paged+cb changed greedy outputs"
        elif not agree:
            print(f"  WARNING: {arch} paged vs dense outputs differ "
                  "(near-tie flips across layouts — not asserted off "
                  "CPU)")
        if arch != "qwen2-vl-2b":
            # the memory claim for SSM/hybrid/enc-dec state: 3 live
            # requests vs 4 always-billed dense slots
            assert mp["peak_state_bytes"] < md["peak_state_bytes"], \
                (arch, mp["peak_state_bytes"], md["peak_state_bytes"])
        if frames is not None:
            assert mp["cross_hits"] > 0, "shared frames never reused"
            assert mp["peak_cross"] == 2, mp["peak_cross"]
        ratio = md["peak_state_bytes"] / max(mp["peak_state_bytes"], 1)
        print(f"  {arch:22s}: agree {int(agree)}  peak state "
              f"{mp['peak_state_bytes']:8d} B paged vs "
              f"{md['peak_state_bytes']:8d} B dense ({ratio:.2f}x)")
        report[arch] = {"greedy_agreement": float(agree),
                        "state_bytes_ratio_dense_over_paged": ratio,
                        "dense": md, "paged": mp}
        csv_rows.append((f"serving/arch_{arch}_state_ratio", 0.0, ratio))
        csv_rows.append((f"serving/arch_{arch}_greedy_agreement", 0.0,
                         float(agree)))
    return report


def sharded_child(*, requests: int = 8, slots: int = 4, max_seq: int = 64,
                  new_tokens: int = 8, seed: int = 3) -> dict:
    """The forced-host-device half of the sharded scenario: serve the
    pinned workload at shards=1 and shards=2 and report throughput,
    per-shard peak KV bytes and greedy agreement. Runs in the child
    process ``_sharded_scenario`` spawns (``--sharded-child``) — the
    parent keeps its real single-device topology."""
    import dataclasses

    import jax

    from repro.configs import get_config, reduced
    from repro.models import lm as lm_mod
    from repro.runtime import Runtime
    from repro.serving.engine import Request, ServeConfig, ServeEngine

    # the pinned geometry from run(), plus n_kv_heads=2 so the 2-wide
    # model axis gets one KV head per shard (reduced gemma-2b's single
    # KV head can't split — it would silently replicate)
    cfg = dataclasses.replace(reduced(get_config("gemma-2b"), vocab=32),
                              head_dim=128, n_kv_heads=2)
    rt = Runtime(impl="auto", q_chunk=64)
    params = lm_mod.lm_init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, max_seq // 2)))
               .astype(np.int32) for _ in range(requests)]

    out: dict = {"config": {"arch": cfg.name, "requests": requests,
                            "batch_slots": slots, "max_seq": max_seq,
                            "new_tokens": new_tokens,
                            "n_kv_heads": cfg.n_kv_heads,
                            "host_devices": jax.device_count()}}
    outputs = {}
    for shards in (1, 2):
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=slots, max_seq=max_seq,
                                      quantize="sp2_4", kv_layout="paged",
                                      page_size=16, shards=shards), rt=rt)
        for i, p in enumerate(prompts):            # warmup: pay compiles
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
        eng.run()
        eng.reset_metrics()
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
        done = eng.run()
        outputs[shards] = {r.rid: r.output for r in done}
        m = eng.metrics()
        out[f"shards_{shards}"] = {
            "tokens_per_s": m["tokens_per_s"],
            "peak_kv_bytes": m["peak_kv_bytes"],
            "peak_kv_bytes_per_shard": m["peak_kv_bytes_per_shard"],
            "kv_sharded": m["kv_sharded"],
            "kv_heads_per_shard": m["kv_heads_per_shard"]}
    out["greedy_agreement"] = float(np.mean(
        [outputs[1][i] == outputs[2][i] for i in range(requests)]))
    return out


def _sharded_scenario(csv_rows) -> dict:
    """Tensor-parallel serving: spawn a child with 8 forced host devices
    (the flag must precede jax backend init, so it cannot be set in this
    process — repro.launch.hostdev owns the pattern), run
    ``sharded_child`` there, and assert the sharded contract: greedy
    outputs bit-identical across shard counts, 2-shard KV head-sharded
    with per-shard peak bytes halved. Keys land in
    BENCH_serving.json["sharded"] for the CI checks job."""
    from repro.launch.hostdev import run_with_host_devices

    print("\n== serving: tensor-parallel, 1 vs 2 shards (child with 8 "
          "host devices) ==")
    env = dict(os.environ)
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = run_with_host_devices(
        [sys.executable, "-m", "benchmarks.serving_bench",
         "--sharded-child"], 8, timeout=1800, env=env, cwd=REPO)
    if r.returncode != 0:
        raise RuntimeError(f"sharded child failed:\n{r.stdout[-2000:]}\n"
                           f"{r.stderr[-4000:]}")
    payload = None
    for line in r.stdout.splitlines():
        if line.startswith("SHARDED_JSON "):
            payload = json.loads(line[len("SHARDED_JSON "):])
    assert payload is not None, f"no SHARDED_JSON line:\n{r.stdout[-2000:]}"

    s1, s2 = payload["shards_1"], payload["shards_2"]
    for n, s in (("1", s1), ("2", s2)):
        print(f"  shards={n}: {s['tokens_per_s']:8.1f} tok/s  "
              f"peak KV/shard {s['peak_kv_bytes_per_shard'] / 2**10:7.2f} "
              f"KiB")
        csv_rows.append((f"serving/sharded_{n}_tok_per_s", 0.0,
                         s["tokens_per_s"]))
        csv_rows.append((f"serving/sharded_{n}_peak_kv_kib_per_shard", 0.0,
                         s["peak_kv_bytes_per_shard"] / 2**10))
    assert payload["greedy_agreement"] == 1.0, payload
    assert s2["kv_sharded"] is True and s2["kv_heads_per_shard"] == 1, s2
    assert s2["peak_kv_bytes_per_shard"] < s1["peak_kv_bytes_per_shard"], \
        (s1, s2)
    print(f"  greedy agreement {payload['greedy_agreement']:.0f}, "
          f"per-shard peak KV {s1['peak_kv_bytes_per_shard']} B -> "
          f"{s2['peak_kv_bytes_per_shard']} B")
    csv_rows.append(("serving/sharded_greedy_agreement", 0.0,
                     payload["greedy_agreement"]))
    return payload


if __name__ == "__main__":
    if "--sharded-child" in sys.argv:
        print("SHARDED_JSON " + json.dumps(sharded_child()))
    else:
        rows: list = []
        run(rows)
