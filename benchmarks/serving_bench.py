"""Dense-vs-paged serving benchmark: same weights, same mixed-length
request batch, both KV layouts — reports throughput, latency percentiles,
page occupancy and peak KV bytes, and checks greedy-output agreement (the
paged engine must be a pure memory-layout change, not a model change).

Standalone:  PYTHONPATH=src python -m benchmarks.serving_bench
From run.py: writes BENCH_serving.json at the repo root.
"""
from __future__ import annotations

import json
import os

import numpy as np

ARTIFACT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..",
                                         "BENCH_serving.json"))


def run(csv_rows, *, requests: int = 10, slots: int = 4, max_seq: int = 64,
        new_tokens: int = 8, out_path: str = ARTIFACT) -> dict:
    import jax
    from repro.configs import get_config, reduced
    from repro.models import lm as lm_mod
    from repro.runtime import Runtime
    from repro.serving.engine import Request, ServeEngine

    cfg = reduced(get_config("gemma-2b"))
    rt = Runtime(impl="auto", q_chunk=64)
    params = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, max_seq // 2)))
               .astype(np.int32) for _ in range(requests)]

    outputs = {}
    result = {"config": {"arch": cfg.name, "requests": requests,
                         "batch_slots": slots, "max_seq": max_seq,
                         "new_tokens": new_tokens}}
    print("\n== serving: dense vs paged KV layout ==")
    for layout in ("dense", "paged"):
        eng = ServeEngine(params, cfg, batch_slots=slots, max_seq=max_seq,
                          quantize="sp2_4", rt=rt, kv_layout=layout)
        # warmup pass: pay every jit compile (the paged engine compiles
        # O(log prefill_chunk) chunk-width variants vs dense's two steps —
        # timing a cold run would misattribute compile time to the layout)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
        eng.run()
        eng.reset_metrics()
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
        done = eng.run()
        outputs[layout] = {r.rid: r.output for r in done}
        m = eng.metrics()
        result[layout] = m
        print(f"  {layout:5s}: {m['tokens_per_s']:8.1f} tok/s  "
              f"p50 {m['latency_p50_ms']:7.0f}ms  "
              f"p95 {m['latency_p95_ms']:7.0f}ms  "
              f"peak KV {m['peak_kv_bytes'] / 2**20:6.2f} MiB  "
              f"occ {m['occupancy_mean']:.2f}/{m['occupancy_peak']:.2f}")
        csv_rows.append((f"serving/{layout}_tok_per_s", 0.0,
                         m["tokens_per_s"]))
        csv_rows.append((f"serving/{layout}_peak_kv_mib", 0.0,
                         m["peak_kv_bytes"] / 2**20))

    agree = float(np.mean([outputs["dense"][i] == outputs["paged"][i]
                           for i in range(requests)]))
    # paging is a memory-layout change, not a model change: on the ref
    # backend the math is identical and any divergence is a bug. On TPU
    # the two layouts use different kernels (flash-decode vs paged online
    # softmax), so near-tie top-1 flips under reduction order are
    # possible — report, don't abort the harness.
    if jax.default_backend() == "cpu":
        assert agree == 1.0, f"dense-vs-paged greedy divergence: {agree}"
    elif agree < 1.0:
        print(f"  WARNING: dense-vs-paged agreement {agree:.3f} < 1.0 "
              "(differing kernel reduction order on this backend)")
    result["greedy_agreement"] = agree
    result["kv_bytes_ratio"] = (result["paged"]["peak_kv_bytes"]
                                / max(result["dense"]["peak_kv_bytes"], 1))
    print(f"  dense-vs-paged greedy agreement: {agree:.2f}  "
          f"(peak KV ratio {result['kv_bytes_ratio']:.2f})")
    csv_rows.append(("serving/greedy_agreement", 0.0, agree))

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2, sort_keys=True)
    print(f"  wrote {out_path}")
    return result


if __name__ == "__main__":
    rows: list = []
    run(rows)
