import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The roofline cells compile against the 512-chip production mesh on a host
# backend; the fused-decode bench times the real single-host serving engine,
# where 512 fake devices would poison every measurement — so the flag is
# only set for the roofline modes.
if "--fused-decode-bench" not in sys.argv:
    from repro.launch.hostdev import set_host_device_count
    set_host_device_count(512)

"""Roofline analysis (deliverable g): per (arch x shape), derive the three
terms from compiled artifacts on the single-pod production mesh:

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

XLA's cost analysis counts While bodies once, so raw numbers from the real
(scanned, chunked) step undercount by the layer count. Methodology
(DESIGN.md §6): compile two UNROLLED cost variants of the same step with
1 and 2 layer-periods (inner chunk scans unrolled too — the algorithm is
unchanged, only the While loops disappear), then

    total = cost(P=1) + (n_periods - 1) * (cost(P=2) - cost(P=1)).

Collective bytes are parsed from the partitioned HLO of the same variants
(operand bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), so they get the identical correction for free.

  PYTHONPATH=src python -m benchmarks.roofline --arch granite-3-8b \
      --shape train_4k
  PYTHONPATH=src python -m benchmarks.roofline --all
"""

import argparse
import dataclasses
import json
import re

import jax

from repro.configs import assigned_archs, get_config  # noqa: E402
from repro.configs.base import LM_SHAPES  # noqa: E402
from repro.compat import cost_analysis_dict  # noqa: E402
from repro.launch.dryrun import parse_collectives  # noqa: E402
from repro.launch.mesh import ambient_mesh, make_production_mesh  # noqa: E402
from repro.launch.steps import build_step  # noqa: E402

from . import hw  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "artifacts")

#: fused-decode bench artifact (repo root, like BENCH_serving.json)
BENCH = os.path.normpath(os.path.join(os.path.dirname(__file__), "..",
                                      "BENCH_roofline.json"))


def _compile_cost_variant(cfg, shape, n_periods: int, mesh, *,
                          fsdp: bool, optimizer: str | None,
                          quantized: bool = True, kv_quant: bool = False):
    vcfg = dataclasses.replace(
        cfg, n_layers=len(cfg.pattern) * n_periods,
        n_enc_layers=n_periods if cfg.enc_dec else cfg.n_enc_layers)
    kw: dict = {"unroll": True}
    if shape.kind == "train":
        kw["optimizer"] = optimizer
    else:
        kw["quantized"] = quantized
        if shape.kind == "decode":
            kw["kv_quant"] = kv_quant
    with ambient_mesh(mesh):
        bundle = build_step(vcfg, shape, mesh, **kw)
        jfn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings,
                      donate_argnums=bundle.donate_argnums)
        compiled = jfn.lower(*bundle.args).compile()
    cost = cost_analysis_dict(compiled)
    coll = parse_collectives(compiled.as_text())
    coll_bytes = sum(c["bytes"] for c in coll["computations"].values())
    n_while = len(coll["whiles"])
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll_bytes": coll_bytes,
            "n_while": n_while}


def analytic_hbm_bytes(cfg, shape, parallelism: str, quantized: bool,
                       kv_quant: bool = False) -> float:
    """Modeled HBM traffic per chip per step. XLA's 'bytes accessed' is an
    un-fused upper bound (every instruction's operands counted as memory
    traffic), so the roofline's memory term uses this explicit model; the
    raw counter is reported alongside as `hlo_bytes_upper`.

    Terms (all per chip):
      weights: resident shard (tp) or full gathered layers (fsdp), read once
               per pass; 3 passes for train (fwd + remat-recompute + bwd),
               1 for inference. Quantized serving reads b/16 of bf16 bytes.
      activations: ~16 r/w of (tokens_loc x d_model) per layer (QKV/FFN
               inputs+outputs, norms, residuals), bf16.
      kv/state: decode reads the full cache shard once per step; prefill
               writes it once.
      optimizer: sharded moments read+write (train).
    """
    n_chips = hw.CHIPS_SINGLE_POD
    n_model = 16 if parallelism == "tp" else 1
    b, s = shape.global_batch, shape.seq_len
    n = cfg.param_count_estimate()
    n_act = cfg.active_param_count_estimate()
    d = cfg.d_model
    L = cfg.n_layers
    w_bytes = 0.5 if quantized and shape.kind != "train" else 2.0

    if shape.kind == "train":
        tokens_loc = b * s / n_chips if parallelism == "fsdp" \
            else b * s / (n_chips / n_model)
        weights = 3.0 * n_act * 2.0 * (1.0 if parallelism == "fsdp"
                                       else 1.0 / n_model)
        acts = tokens_loc * d * L * 16 * 2.0 * 3 / 2      # fwd+bwd+remat
        opt = 16.0 * n / n_chips                          # moments r/w
        return weights + acts + opt

    # serving: weights shard per chip ("cp" prefill gathers full weights)
    weights = n_act * w_bytes * (1.0 if parallelism == "cp"
                                 else 1.0 / n_model)
    n_attn_layers = sum(1 for p in cfg.pattern
                        if p.split("+")[0] in ("attn", "xdec")) \
        * cfg.n_periods
    # quantized KV: uint8 codes + one f32 scale per (token, head) side
    # (scheme-independent layout — docs/QUANTIZATION.md); else bf16
    from repro.core.spx import kv_token_side_bytes
    kv_elem_bytes = (kv_token_side_bytes(cfg.dh) / cfg.dh if kv_quant
                     else 2.0)
    kv_total = (b * n_attn_layers * cfg.n_kv_heads * s * cfg.dh * 2
                * kv_elem_bytes / n_chips)
    if shape.kind == "decode":
        tokens_loc = b / (n_chips / n_model)
        acts = tokens_loc * d * L * 16 * 2.0
        return weights + kv_total + acts
    tokens_loc = (b * s / n_chips if parallelism == "cp"
                  else b * s / (n_chips / n_model))
    acts = tokens_loc * d * L * 16 * 2.0
    # cp attention reads the gathered K/V per layer
    if parallelism == "cp":
        b_loc = max(b / 16, 1)
        acts += (n_attn_layers * b_loc * cfg.n_kv_heads * s * cfg.dh * 2
                 * 2.0)
    return weights + acts + kv_total


def analytic_collective_bytes(cfg, shape, parallelism: str) -> float:
    """Modeled ICI traffic per chip per step (the parsed HLO numbers carry
    an XLA-CPU artifact: converts fused into collectives upcast bf16
    payloads to f32; reported alongside as `hlo_coll`).

    fsdp train: params gathered once per pass (x2: fwd+bwd-recompute) +
                grads reduce-scattered once: ~3 x 2 x N_active bytes.
    tp train:   per attn/ffn block, SP gather + reduce-scatter of the
                (tokens_loc x d) activation: ~4 x L x tokens x d x 2B.
    tp serving: one all-reduce of (tokens_loc x d) per layer + flash-decode
                LSE merges (tiny).
    """
    n_chips = hw.CHIPS_SINGLE_POD
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    n_act = cfg.active_param_count_estimate()
    if shape.kind == "train":
        if parallelism == "fsdp":
            return 3.0 * 2.0 * n_act
        tokens_loc = b * s / (n_chips / 16)
        return 4.0 * L * tokens_loc * d * 2.0 + 2.0 * 2.0 * n_act / 16
    if parallelism == "cp":
        # per layer: gathered (quantized) weights + gathered GQA K/V
        n_attn_layers = sum(1 for p in cfg.pattern
                            if p.split("+")[0] in ("attn", "xdec")) \
            * cfg.n_periods
        b_loc = max(b / 16, 1)
        kv_gather = (n_attn_layers * b_loc * cfg.n_kv_heads * s * cfg.dh
                     * 2 * 2.0)
        return n_act * 0.5 + kv_gather
    tokens = (b if shape.kind == "decode" else b * s) / (n_chips / 16)
    return 2.0 * L * tokens * d * 2.0


def analytic_model_flops(cfg, shape) -> float:
    """Useful FLOPs per step, global: 6·N_active·tokens for train,
    2·N_active·tokens for inference, plus causal attention terms."""
    n_act = cfg.active_param_count_estimate()
    b, s = shape.global_batch, shape.seq_len
    n_attn_layers = sum(1 for p in cfg.pattern
                        if p.split("+")[0] in ("attn", "xdec")) \
        * cfg.n_periods
    dh, hq = cfg.dh, cfg.n_heads
    if shape.kind == "train":
        core = 6.0 * n_act * b * s
        attn = 6.0 * n_attn_layers * b * (s * s / 2) * hq * dh * 2
        return core + attn
    if shape.kind == "prefill":
        core = 2.0 * n_act * b * s
        attn = 2.0 * n_attn_layers * b * (s * s / 2) * hq * dh * 2
        return core + attn
    # decode: one token per sequence against an s-deep cache
    core = 2.0 * n_act * b
    attn = 2.0 * n_attn_layers * b * s * hq * dh * 2
    return core + attn


def run_cell(arch: str, shape_name: str, *, quantized: bool = True,
             kv_quant: bool = False, verbose: bool = True) -> dict | None:
    cfg = get_config(arch)
    shape = next(s for s in LM_SHAPES if s.name == shape_name)
    for s, why in cfg.shapes():
        if s.name == shape_name and why:
            return {"arch": arch, "shape": shape_name, "status": why}

    mesh = make_production_mesh(multi_pod=False)
    # policy decisions must come from the FULL config, not the 1-period
    # variant (FSDP / optimizer choice change collectives per layer)
    from repro.sharding import make_policy
    fsdp = make_policy(cfg, mesh).fsdp
    optimizer = ("adamw_q8" if cfg.param_count_estimate() > 30e9
                 else "adamw")

    c1 = _compile_cost_variant(cfg, shape, 1, mesh, fsdp=fsdp,
                               optimizer=optimizer, quantized=quantized,
                               kv_quant=kv_quant)
    c2 = _compile_cost_variant(cfg, shape, 2, mesh, fsdp=fsdp,
                               optimizer=optimizer, quantized=quantized,
                               kv_quant=kv_quant)
    P = cfg.n_periods
    corr = {k: c1[k] + (P - 1) * (c2[k] - c1[k])
            for k in ("flops", "bytes", "coll_bytes")}

    if shape.kind == "train":
        parallelism = ("fsdp" if (cfg.param_count_estimate() <= 30e9
                                  and shape.global_batch % 256 == 0)
                       else "tp")
    elif shape.kind == "prefill" and cfg.param_count_estimate() <= 30e9 \
            and shape.seq_len % 16 == 0 and shape.global_batch % 16 == 0 \
            and not cfg.enc_dec:
        parallelism = "cp"       # context-parallel prefill (§Perf cell 2)
    else:
        parallelism = "tp"
    mem_bytes = analytic_hbm_bytes(cfg, shape, parallelism, quantized,
                                   kv_quant=kv_quant)
    coll_bytes = analytic_collective_bytes(cfg, shape, parallelism)

    t_compute = corr["flops"] / hw.PEAK_BF16_FLOPS
    t_memory = mem_bytes / hw.HBM_BW
    t_coll = coll_bytes / hw.ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    model_flops = analytic_model_flops(cfg, shape)
    model_per_chip = model_flops / hw.CHIPS_SINGLE_POD
    hlo_ratio = model_per_chip / max(corr["flops"], 1.0)
    mfu_bound = (model_per_chip / hw.PEAK_BF16_FLOPS) / max(bound, 1e-30)

    result = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": "16x16", "quantized_serving": quantized,
        "kv_quant": kv_quant,
        "parallelism": parallelism,
        "per_chip": {"flops": corr["flops"],
                     "mem_bytes_model": mem_bytes,
                     "coll_bytes_model": coll_bytes,
                     "hlo_bytes_upper": corr["bytes"],
                     "hlo_coll_parsed": corr["coll_bytes"]},
        "raw_p1": c1, "raw_p2": c2, "n_periods": P,
        "terms_s": terms, "dominant": dominant, "bound_s": bound,
        "model_flops_global": model_flops,
        "model_flops_per_chip": model_per_chip,
        "useful_flops_ratio": hlo_ratio,
        "roofline_fraction": mfu_bound,
        "residual_whiles": c1["n_while"],
    }
    if verbose:
        print(f"[{arch} x {shape_name}] {parallelism} dominant={dominant} "
              f"bound={bound*1e3:.2f}ms "
              f"(c={t_compute*1e3:.2f} m={t_memory*1e3:.2f} "
              f"x={t_coll*1e3:.2f}) useful/HLO={hlo_ratio:.2f} "
              f"roofline_frac={mfu_bound:.2f}")
    os.makedirs(ART, exist_ok=True)
    tag = "" if quantized else "_dense"
    if kv_quant:
        tag += "_kv8"
    fname = f"roofline_{arch.replace('.', '_')}_{shape_name}{tag}.json"
    with open(os.path.join(ART, fname), "w") as f:
        json.dump(result, f, indent=1)
    return result


def fused_decode_bench(csv_rows, *, requests: int = 6, slots: int = 2,
                       max_seq: int = 512, new_tokens: int = 24,
                       spec_k: int = 3, seed: int = 3,
                       out_path: str = BENCH) -> dict:
    """Fused vs unfused decode step on the pinned serving workload:
    the ragged decode megakernel (one attention dispatch per tick, spec
    verify included, in-kernel LUT dequant) against the pre-megakernel
    path (per-call paged-attention kernel for plain ticks + full-width
    page-gather verify for draft ticks).

    Reports, per KV axis (plain f32 pages / SPx codes+scale pages):
      * measured decode throughput (warmup pass pays every compile, then
        reset_metrics + a timed pass — serving_bench's protocol),
      * attention ops traced per decode tick (the trace-time op-call
        counters; the fused path is asserted =1 in tests/test_fused_decode),
      * modeled HBM bytes per decode tick (the gather path reads the full
        block-table width and materializes rep-expanded f32 K/V; the
        megakernel streams only touched pages once and keeps the <=1KiB
        codebook LUT in VMEM),
      * the planner's FusedDecodePlan for the tick's geometry.

    On CPU with the DEFAULT (pinned) workload, asserts greedy outputs are
    bit-identical fused vs unfused and fused throughput >= unfused — the
    megakernel is a dispatch/memory optimization, not a numerics change.
    Writes BENCH_roofline.json at the repo root (run.py + CI upload it).
    """
    import time

    import numpy as np
    from repro.configs import get_config, reduced
    from repro.core.spx import kv_token_side_bytes
    from repro.kernels import ops
    from repro.models import lm as lm_mod
    from repro.runtime import Runtime, planner
    from repro.serving.engine import Request, ServeConfig, ServeEngine

    # serving_bench's pinned geometry: dh=128 keeps the SPx byte ratio
    # representative, vocab=32 keeps greedy argmaxes away from near-ties
    cfg = dataclasses.replace(reduced(get_config("gemma-2b"), vocab=32),
                              head_dim=128)
    rt = Runtime(impl="auto", q_chunk=64)
    params = lm_mod.lm_init(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    # tiled-motif prompts: the structure prompt-lookup drafting feeds on,
    # so the verify window (the megakernel's q_len > 1 rows) stays hot
    prompts = [np.tile(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                       4) for _ in range(requests)]

    w = spec_k + 1
    rep = cfg.n_heads // cfg.n_kv_heads
    n_attn_layers = sum(1 for p in cfg.pattern
                        if p.split("+")[0] in ("attn", "xdec")) \
        * cfg.n_periods
    axes = {"paged": rt,
            "paged-spx": rt.replace(kv_quant=True, kv_scheme="spx_8_x3")}
    pinned = (requests, slots, max_seq, new_tokens, spec_k, seed) \
        == (6, 2, 512, 24, 3, 3)
    result: dict = {"config": {"arch": cfg.name, "requests": requests,
                               "batch_slots": slots, "max_seq": max_seq,
                               "new_tokens": new_tokens, "spec_k": spec_k,
                               "gqa_rep": rep,
                               "n_attn_layers": n_attn_layers}}
    print("\n== decode megakernel: fused vs unfused, plain and SPx KV ==")
    for axis, ert in axes.items():
        outs, mets = {}, {}
        for fused in (True, False):
            eng = ServeEngine(params, cfg,
                              ServeConfig(batch_slots=slots, max_seq=max_seq,
                                          quantize="sp2_4", kv_layout="paged",
                                          spec_decode=True, spec_k=spec_k,
                                          fused_decode=fused),
                              rt=ert)
            ops.reset_op_calls()
            for i, p in enumerate(prompts):        # warmup: pay compiles
                eng.submit(Request(rid=i, prompt=p,
                                   max_new_tokens=new_tokens))
            eng.run()
            # every step is compiled now, so the counters hold ops traced,
            # i.e. attention dispatches per compiled tick (layer-scanned)
            traced = ops.op_calls()
            # best-of-3 measured passes: one pass is ~0.1s on CPU, well
            # inside scheduler noise; max-of-3 is the standard antidote
            m, dt = None, float("inf")
            for _ in range(3):
                eng.reset_metrics()
                t0 = time.monotonic()
                for i, p in enumerate(prompts):
                    eng.submit(Request(rid=i, prompt=p,
                                       max_new_tokens=new_tokens))
                outs[fused] = {r.rid: r.output for r in eng.run()}
                dt = min(dt, time.monotonic() - t0)
                mm = eng.metrics()
                if m is None or mm["tokens_per_s"] > m["tokens_per_s"]:
                    m = mm
            mets[fused] = m
            decode_ops = {k: v for k, v in traced.items()
                          if "paged" in k or "decode" in k}
            ps = m["page_size"]
            tok_bytes = (kv_token_side_bytes(cfg.dh)
                         if ert.kv_quant else 4 * cfg.dh)
            s_max = -(-max_seq // ps) * ps          # block-table width
            ctx_mean = float(np.mean([len(p) for p in prompts])
                             + new_tokens / 2)
            s_touch = -(-int(ctx_mean + w) // ps) * ps
            if fused:
                plan = planner.plan_fused_decode(
                    cfg.dh, rep=rep, w=w, page_size=ps, act_bytes=4,
                    kv_scheme=ert.kv_scheme if ert.kv_quant else None)
                # streams each touched page once; LUT + q rows ride along
                kv_tick = (2 * cfg.n_kv_heads * s_touch * tok_bytes
                           + plan.lut_bytes
                           + plan.rows * cfg.dh * 4)
                bytes_tick = kv_tick * n_attn_layers * slots
                result.setdefault(axis, {})["plan"] = \
                    dataclasses.asdict(plan)
            else:
                # gather reads the FULL block-table width, materializes a
                # contiguous f32 copy (write+read), then rep-expands it
                # to Hq for the GQA einsum (write+read again)
                kv_tick = (2 * cfg.n_kv_heads * s_max * tok_bytes
                           + 2 * cfg.n_kv_heads * s_max * cfg.dh * 4 * 2
                           + (2 * cfg.n_heads * s_max * cfg.dh * 4 * 2
                              if rep > 1 else 0))
                bytes_tick = kv_tick * n_attn_layers * slots
            tag = "fused  " if fused else "unfused"
            print(f"  {axis:10s} {tag}: {m['tokens_per_s']:8.1f} tok/s  "
                  f"calls {m['model_calls']:3d}  accept "
                  f"{m['draft_acceptance_rate']:.2f}  "
                  f"~{bytes_tick / 2**20:6.2f} MiB/tick  "
                  f"ops/trace {decode_ops}")
            result.setdefault(axis, {})[
                "fused" if fused else "unfused"] = {
                    "tokens_per_s": m["tokens_per_s"],
                    "model_calls": m["model_calls"],
                    "draft_acceptance_rate": m["draft_acceptance_rate"],
                    "wall_s": dt,
                    "attention_ops_traced": decode_ops,
                    "modeled_kv_bytes_per_tick": bytes_tick,
                }
        agree = outs[True] == outs[False]
        speedup = (mets[True]["tokens_per_s"]
                   / max(mets[False]["tokens_per_s"], 1e-9))
        result[axis]["greedy_agreement"] = float(agree)
        result[axis]["fused_speedup"] = speedup
        fb = result[axis]["fused"]["modeled_kv_bytes_per_tick"]
        ub = result[axis]["unfused"]["modeled_kv_bytes_per_tick"]
        result[axis]["modeled_bytes_ratio_unfused_over_fused"] = \
            ub / max(fb, 1)
        print(f"  {axis:10s} fused speedup {speedup:.2f}x, modeled "
              f"bytes/tick ratio {ub / fb:.1f}x, agree {agree}")
        if jax.default_backend() == "cpu" and pinned:
            assert agree, f"{axis}: megakernel changed greedy outputs"
            assert speedup >= 1.0, \
                f"{axis}: fused decode slower than unfused ({speedup:.2f}x)"
        elif not agree:
            print(f"  WARNING: {axis} fused vs unfused outputs differ "
                  "(near-tie flips across reduction orders — not "
                  "asserted off the pinned CPU workload)")
        csv_rows.append((f"roofline/fused_decode_{axis}_tok_per_s", 0.0,
                         mets[True]["tokens_per_s"]))
        csv_rows.append((f"roofline/fused_decode_{axis}_speedup", 0.0,
                         speedup))
    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2, sort_keys=True)
    print(f"  wrote {out_path}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dense-baseline", action="store_true",
                    help="also run serve shapes with UNquantized weights "
                    "(pre-paper baseline)")
    ap.add_argument("--fused-decode-bench", action="store_true",
                    help="time the ragged decode megakernel against the "
                    "per-call kernel + page-gather path and write "
                    "BENCH_roofline.json (skips the roofline cells)")
    args = ap.parse_args()

    if args.fused_decode_bench:
        fused_decode_bench([])
        return 0

    archs = assigned_archs() if (args.all or not args.arch) else [args.arch]
    results = []
    for a in archs:
        cfg = get_config(a)
        for s, why in cfg.shapes():
            if args.shape and s.name != args.shape:
                continue
            if why:
                results.append({"arch": a, "shape": s.name, "status": why})
                print(f"[{a} x {s.name}] {why}")
                continue
            try:
                results.append(run_cell(a, s.name))
                if args.dense_baseline and s.kind != "train":
                    results.append(run_cell(a, s.name, quantized=False))
            except Exception as e:
                import traceback
                traceback.print_exc()
                results.append({"arch": a, "shape": s.name,
                                "status": f"FAILED: {e}"})
    n_bad = sum(1 for r in results if r and r["status"].startswith("FAIL"))
    print(f"\n{len(results)} cells, {n_bad} failures")
    return 1 if n_bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
