"""Paper Figure 5 analog: per-sample inference time vs batch size. The
paper's figure shows amortization of fixed costs over the batch; we measure
the same curve for the dense and SPx-quantized paths on this host, plus the
pipeline-feasibility margin (core/pipeline.py) for the same matmuls on the
TPU target — the §3.1 load/compute-decoupling argument, quantified."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import plan_matmul_blocks
from repro.data.mnist import make_dataset
from repro.models.mlp_mnist import PAPER_LAYERS, paper_mlp_apply, \
    paper_mlp_init
from repro.nn.layers import quantize_params
from repro.runtime import Runtime

BATCHES = (1, 8, 64, 256, 1024)


def run(csv_rows: list):
    params = paper_mlp_init(jax.random.PRNGKey(0))
    qp = quantize_params(params, "sp2_4", min_size=1024)
    rtq = Runtime(impl="auto")
    x_all, _ = make_dataset(max(BATCHES), seed=9)

    print("\n== Fig.5 analog: us/sample vs batch (host-measured) ==")
    fp = jax.jit(lambda p, xx: paper_mlp_apply(p, xx))
    qf = jax.jit(lambda p, xx: paper_mlp_apply(p, xx, rtq))
    for b in BATCHES:
        x = jnp.asarray(x_all[:b])
        for name, fn, pp in (("fp32", fp, params), ("sp2_4", qf, qp)):
            jax.block_until_ready(fn(pp, x))
            t0 = time.monotonic()
            for _ in range(30):
                jax.block_until_ready(fn(pp, x))
            t = (time.monotonic() - t0) / 30 / b
            print(f"  B={b:5d} {name:6s}: {t*1e6:8.2f} us/sample")
            csv_rows.append((f"fig5/{name}_b{b}", t * 1e6, b))

    print("\n== pipeline feasibility on TPU target (paper §3.1 condition) ==")
    for (m, n, k) in ((1024, 128, 784), (4096, 4096, 4096),
                      (8192, 12800, 4096)):
        for bits in (16, 4):
            plan = plan_matmul_blocks(m, n, k, weight_bits=bits)
            ok = "pipelined" if plan.pipelined else "LOAD-BOUND"
            print(f"  {m}x{n}x{k} w{bits}: blocks ({plan.bm},{plan.bn},"
                  f"{plan.bk}) margin {plan.margin:5.2f}x -> {ok}")
            csv_rows.append((f"fig5/pipe_{m}x{n}x{k}_w{bits}",
                             plan.margin, 1.0 if plan.pipelined else 0.0))
    return csv_rows
