"""End-to-end LM training: a ~100M-param dense transformer (granite family,
shrunk) trained for a few hundred steps on the synthetic Markov corpus,
with checkpointing + resume and optional SPx gradient compression.

  PYTHONPATH=src python examples/train_llm.py --steps 300
  (add --tiny for a seconds-scale CI run)
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.models import lm as lm_mod
from repro.nn.layers import param_count
from repro.runtime import Runtime
from repro.training import (GradCompressor, TrainConfig, TrainLoop,
                            make_optimizer)


def make_100m_cfg():
    base = get_config("granite-3-8b")
    return dataclasses.replace(
        base, name="granite-100m", n_layers=8, d_model=640, n_heads=8,
        n_kv_heads=2, head_dim=80, d_ff=1792, vocab_size=8192)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", default=None)
    args = ap.parse_args(argv)

    cfg = make_100m_cfg()
    if args.tiny:
        cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, n_heads=4,
                                  n_kv_heads=2, head_dim=32, d_ff=256,
                                  vocab_size=512)
        args.steps = min(args.steps, 30)
        args.seq, args.batch = 64, 8

    rt = Runtime(impl="auto", q_chunk=min(512, args.seq))
    data = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=0)

    def init_params():
        p = lm_mod.lm_init(jax.random.PRNGKey(0), cfg)
        print(f"[train_llm] {cfg.name}: {param_count(p)/1e6:.1f}M params")
        return p

    comp = GradCompressor(args.compress_grads) if args.compress_grads else None
    tc = TrainConfig(max_steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=100, log_every=10)
    loop = TrainLoop(
        lambda p, b: lm_mod.lm_loss(p, b, cfg, rt),
        make_optimizer("adamw", lr=3e-3), init_params, iter(data), tc,
        compressor=comp)
    try:
        params, hist = loop.run()
        uniform = float(np.log(cfg.vocab_size))
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        print(f"[train_llm] loss {first:.3f} -> {last:.3f} "
              f"(uniform {uniform:.3f}); structure learned: "
              f"{'yes' if last < uniform * 0.75 else 'no'}")
        return hist
    finally:
        data.close()


if __name__ == "__main__":
    main()
