"""Paper §4.2 analog: Q-learning with an MLP function approximator, with
the inference path (action selection) running through the SPx-quantized
pipelined matmul.

OpenAI Gym isn't installable offline, so the environment is a self-contained
numpy CartPole-class control task (pole balancing, 4-dim state, 2 actions)
— the same role Acrobot-v1 plays in the paper: a control loop whose policy
evaluation is MLP inference at the edge.

  PYTHONPATH=src python examples/rl_qlearning.py [--episodes 120]
"""
import argparse
import collections
import random

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.mlp_mnist import mlp_net_apply, mlp_net_init
from repro.nn.layers import quantize_params
from repro.runtime import Runtime
from repro.training import make_optimizer


class CartPole:
    """Minimal cart-pole (Barto-Sutton dynamics), 200-step episodes."""

    def __init__(self, seed=0):
        self.rng = np.random.default_rng(seed)
        self.state = None

    def reset(self):
        self.state = self.rng.uniform(-0.05, 0.05, 4)
        self.t = 0
        return self.state.copy()

    def step(self, action):
        x, x_dot, th, th_dot = self.state
        force = 10.0 if action == 1 else -10.0
        costh, sinth = np.cos(th), np.sin(th)
        temp = (force + 0.05 * th_dot ** 2 * sinth) / 1.1
        th_acc = (9.8 * sinth - costh * temp) / \
            (0.5 * (4.0 / 3.0 - 0.1 * costh ** 2 / 1.1))
        x_acc = temp - 0.05 * th_acc * costh / 1.1
        dt = 0.02
        self.state = np.array([x + dt * x_dot, x_dot + dt * x_acc,
                               th + dt * th_dot, th_dot + dt * th_acc])
        self.t += 1
        done = (abs(self.state[0]) > 2.4 or abs(self.state[2]) > 0.21
                or self.t >= 200)
        return self.state.copy(), 1.0, done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    random.seed(args.seed)
    env = CartPole(args.seed)
    qnet = mlp_net_init(jax.random.PRNGKey(args.seed), (4, 64, 64, 2))
    opt = make_optimizer("adamw", lr=1e-3)
    state = opt.init(qnet)
    buffer: collections.deque = collections.deque(maxlen=10000)
    gamma, eps = 0.99, 1.0

    apply_q = jax.jit(lambda p, s: mlp_net_apply(p, s, act=jax.nn.relu))

    @jax.jit
    def train_step(params, state, s, a, r, s2, d):
        q_next = jnp.max(mlp_net_apply(params, s2, act=jax.nn.relu), axis=-1)
        target = r + gamma * q_next * (1.0 - d)

        def loss_fn(p):
            q = mlp_net_apply(p, s, act=jax.nn.relu)
            q_sa = jnp.take_along_axis(q, a[:, None], axis=-1)[:, 0]
            return jnp.mean((q_sa - jax.lax.stop_gradient(target)) ** 2)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(params, grads, state)
        return params, state, loss

    returns = []
    for ep in range(args.episodes):
        s = env.reset()
        total = 0.0
        done = False
        while not done:
            if random.random() < eps:
                a = random.randrange(2)
            else:
                a = int(jnp.argmax(apply_q(qnet, jnp.asarray(s)[None]))[()])
            s2, r, done = env.step(a)
            buffer.append((s, a, r, s2, float(done)))
            s = s2
            total += r
            if len(buffer) >= 128:
                batch = random.sample(buffer, 64)
                bs, ba, br, bs2, bd = map(np.array, zip(*batch))
                qnet, state, _ = train_step(
                    qnet, state, jnp.asarray(bs, jnp.float32),
                    jnp.asarray(ba, jnp.int32), jnp.asarray(br, jnp.float32),
                    jnp.asarray(bs2, jnp.float32), jnp.asarray(bd, jnp.float32))
        eps = max(0.05, eps * 0.97)
        returns.append(total)
        if (ep + 1) % 20 == 0:
            print(f"episode {ep + 1}: avg return (last 20) "
                  f"{np.mean(returns[-20:]):.1f} eps={eps:.2f}")

    # deploy the learned Q-network through the quantized inference path
    print("\n== quantized policy evaluation (the paper's edge-inference "
          "setting) ==")
    rt = Runtime(impl="auto")
    for scheme in (None, "sp2_8", "sp2_4"):
        qp = quantize_params(qnet, scheme, min_size=256) if scheme else qnet
        evals = []
        for trial in range(10):
            env_eval = CartPole(1000 + trial)
            s = env_eval.reset()
            done, total = False, 0.0
            while not done:
                q = mlp_net_apply(qp, jnp.asarray(s)[None], act=jax.nn.relu,
                                  rt=rt)
                s, r, done = env_eval.step(int(jnp.argmax(q[0])))
                total += r
            evals.append(total)
        print(f"  {scheme or 'float32':8s}: avg return {np.mean(evals):.1f}")
    return returns


if __name__ == "__main__":
    main()
