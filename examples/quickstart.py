"""Quickstart: the paper's §4.1 experiment end to end.

Trains the 784-128-10 sigmoid MLP (MSE loss, SGD, B=64, eta=0.5 — exactly
Eq. 4.4-4.6) on the synthetic MNIST-like dataset, then deploys it through
the SPx-quantized pipelined matmul path and compares accuracy + per-sample
time across quantization schemes (the §3.2 story: PoT collapses at the
tails, SP2/SPx recover).

  PYTHONPATH=src python examples/quickstart.py [--epochs 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spx
from repro.data.mnist import SynthDigits
from repro.models.mlp_mnist import (paper_mlp_init, paper_mlp_loss,
                                    paper_mlp_predict)
from repro.nn.layers import quantize_params
from repro.runtime import Runtime
from repro.training import make_optimizer


def accuracy(params, x, y, rt=None):
    pred = paper_mlp_predict(params, jnp.asarray(x), rt)
    return float(jnp.mean((pred == jnp.asarray(y)).astype(jnp.float32)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)     # paper: B=64
    ap.add_argument("--lr", type=float, default=0.5)     # paper: eta=0.5
    args = ap.parse_args(argv)

    data = SynthDigits(n_train=8192, n_test=2048, batch_size=args.batch)
    params = paper_mlp_init(jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", lr=args.lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(paper_mlp_loss)(params, x, y)
        params, state = opt.update(params, grads, state)
        return params, state, loss

    print(f"== training {args.epochs} epochs (SGD lr={args.lr} B={args.batch},"
          " MSE loss — paper Eq. 4.4-4.6) ==")
    for epoch in range(args.epochs):
        losses = []
        for x, y in data.batches():
            params, state, loss = step(params, state, jnp.asarray(x),
                                       jnp.asarray(y))
            losses.append(float(loss))
        acc = accuracy(params, data.x_test, data.y_test)
        print(f"epoch {epoch + 1}: loss {np.mean(losses):.4f} "
              f"test acc {acc:.3f}")

    print("\n== quantized inference (paper §3.2 schemes) ==")
    x_test = jnp.asarray(data.x_test)
    results = {}
    fp_acc = accuracy(params, data.x_test, data.y_test)
    results["float32"] = fp_acc
    for scheme in ("uniform4", "pot4", "sp2_4", "uniform8", "sp2_8",
                   "spx_8_x3"):
        qp = quantize_params(params, scheme, min_size=1024)
        rt = Runtime(impl="auto")
        acc = accuracy(qp, data.x_test, data.y_test, rt)
        width = spx.code_width(spx.scheme_levels(scheme))
        results[scheme] = acc
        print(f"  {scheme:10s} ({width}-bit): acc {acc:.3f} "
              f"(drop {fp_acc - acc:+.3f})")

    # per-sample timing (Table 1 analog on this host)
    bench = jax.jit(lambda p, x: paper_mlp_predict(p, x))
    bench(params, x_test).block_until_ready()
    t0 = time.monotonic()
    for _ in range(20):
        bench(params, x_test).block_until_ready()
    t_fp = (time.monotonic() - t0) / (20 * len(data.x_test))
    print(f"\nper-sample inference (this host, fp32): {t_fp * 1e6:.2f} us")
    print("(cross-device comparison incl. modeled TPU time: "
          "benchmarks/table1.py)")
    return results


if __name__ == "__main__":
    main()
