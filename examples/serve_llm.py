"""Batched LM serving with SPx-quantized weights: train a small LM briefly
(so the weights are non-random), quantize to the paper's 4-bit SP2, and
serve a batch of requests through the engine — comparing dense vs quantized
weights AND dense vs paged KV layouts (throughput, occupancy, agreement).

  PYTHONPATH=src python examples/serve_llm.py
  PYTHONPATH=src python examples/serve_llm.py --arch xlstm-350m

--arch accepts any bundled config. Every family serves paged through the
unified state cache (docs/SERVING.md); the example runs the feature axes
the architecture's layer pattern supports — prefix cache and speculative
decoding are attention-pattern features, so an SSM/hybrid arch compares
the weight and KV-layout axes only, and an enc-dec arch (whisper-small,
served on synthetic input frames, no train loop) adds the speculation
axis back.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data.tokens import TokenStream
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving.engine import Request, ServeConfig, ServeEngine
from repro.training import TrainConfig, TrainLoop, make_optimizer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b",
                    help="any bundled config (SSM/hybrid/enc-dec/M-RoPE "
                         "included — each runs the axes its layer "
                         "pattern supports)")
    ap.add_argument("--train-steps", type=int, default=40)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    cfg = (reduced(cfg, d_model=128, vocab=512)
           if args.arch == "gemma-2b" else reduced(cfg))
    rt = Runtime(impl="auto", q_chunk=64)
    mixers = {s.split("+")[0] for s in cfg.pattern}
    recurrent = bool(mixers & {"mamba", "mlstm", "slstm"})

    if cfg.enc_dec:
        # enc-dec: random-init weights, synthetic input frames (two
        # distinct inputs alternate, so the shared cross-KV region of
        # the state cache sees encoder-pass reuse)
        params = encdec_mod.encdec_init(jax.random.PRNGKey(0), cfg)
        frame_sets = np.random.default_rng(1).standard_normal(
            (2, cfg.enc_seq_len, cfg.d_model)).astype(np.float32)
    else:
        # brief training so serving runs on learned weights (the LM
        # assembly covers dense/MoE/SSM/hybrid/M-RoPE patterns alike)
        frame_sets = None
        data = TokenStream(cfg.vocab_size, 8, 64, seed=0)
        tc = TrainConfig(max_steps=args.train_steps, log_every=20)
        loop = TrainLoop(lambda p, b: lm_mod.lm_loss(p, b, cfg, rt),
                         make_optimizer("adamw", lr=3e-3),
                         lambda: lm_mod.lm_init(jax.random.PRNGKey(0),
                                                cfg),
                         iter(data), tc)
        params, _ = loop.run()
        data.close()

    rng = np.random.default_rng(0)
    # every request opens with the same 16-token system prompt — the
    # prefix-cache axis below shares its KV pages across requests
    sys_prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    prompts = [np.concatenate(
        [sys_prompt,
         rng.integers(0, cfg.vocab_size,
                      int(rng.integers(4, 16))).astype(np.int32)])
        for _ in range(args.requests)]

    # axes: weights (dense vs sp2_4) x KV (dense slots, paged, paged +
    # SPx-quantized codes+scale pages — docs/QUANTIZATION.md) x shared
    # prefix pages x prompt-lookup speculative decoding (docs/SERVING.md).
    # Pattern-gated features are left off the matrix where the engine
    # would reject them (recurrent slabs cannot prefix-share or roll
    # back drafts; enc-dec decoder KV depends on the encoder output).
    axes = [(None, "dense", False, False, False),
            ("sp2_4", "dense", False, False, False),
            ("sp2_4", "paged", False, False, False)]
    if not (recurrent or cfg.enc_dec):
        axes += [("sp2_4", "paged", True, False, False),
                 ("sp2_4", "paged", False, True, False)]
    if not recurrent:
        axes += [("sp2_4", "paged", False, False, True)]

    results = {}
    for scheme, layout, kvq, share, spec in axes:
        tag = (f"{scheme or 'dense'}/{layout}{'+kvq' if kvq else ''}"
               f"{'+share' if share else ''}{'+spec' if spec else ''}")
        ert = rt.replace(kv_quant=True, kv_scheme="spx_8_x3") if kvq else rt
        # explicit bools (not None) so a REPRO_PREFIX_CACHE=1 /
        # REPRO_SPEC_K environment can't silently flip the other axes
        eng = ServeEngine(params, cfg,
                          ServeConfig(batch_slots=4, max_seq=64,
                                      quantize=scheme, kv_layout=layout,
                                      prefix_cache=share, spec_decode=spec),
                          rt=ert)
        t0 = time.monotonic()
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p,
                               max_new_tokens=args.new_tokens,
                               frames=(None if frame_sets is None
                                       else frame_sets[i % 2])))
        done = eng.run()
        dt = time.monotonic() - t0
        n_tok = sum(len(r.output) for r in done)
        results[tag] = {r.rid: r.output for r in done}
        m = eng.metrics()
        extra = (f" pages {m['n_pages']}x{m['page_size']} "
                 f"occ {m['occupancy_mean']:.2f}"
                 if layout == "paged" else "")
        if share:
            extra += (f" hits {m['prefix_hits']}"
                      f" skipped {m['prefill_tokens_skipped']}tok")
        if spec:
            extra += (f" calls {m['model_calls']}"
                      f" acc {m['draft_acceptance_rate']:.2f}")
        print(f"[serve_llm] {tag:12s}: {n_tok} tokens in {dt:.2f}s "
              f"({n_tok / dt:.0f} tok/s) peak KV "
              f"{m['peak_kv_bytes'] / 2**10:.0f} KiB{extra}")

    # agreements, whichever axes ran: lossy comparisons (the weights
    # axis, SPx-quantized KV pages) report token-level agreement; every
    # same-weights axis (layout, sharing, speculation) is exact by
    # construction and reports exact-output agreement
    def tok_agree(a, b):
        return float(np.mean([
            np.mean(np.array(results[a][i]) == np.array(results[b][i]))
            for i in range(args.requests)]))

    def exact_agree(a, b):
        return float(np.mean([results[a][i] == results[b][i]
                              for i in range(args.requests)]))

    print(f"[serve_llm] dense vs sp2_4 greedy-token agreement: "
          f"{tok_agree('dense/dense', 'sp2_4/dense'):.2f}")
    print(f"[serve_llm] dense vs paged KV exact-output agreement: "
          f"{exact_agree('sp2_4/dense', 'sp2_4/paged'):.2f}")
    if "sp2_4/paged+kvq" in results:
        print(f"[serve_llm] f32 vs SPx-quantized KV pages token "
              f"agreement: "
              f"{tok_agree('sp2_4/paged', 'sp2_4/paged+kvq'):.2f}")
    if "sp2_4/paged+share" in results:
        print(f"[serve_llm] private vs shared prefix pages exact-output "
              f"agreement: "
              f"{exact_agree('sp2_4/paged', 'sp2_4/paged+share'):.2f}")
    if "sp2_4/paged+spec" in results:
        print(f"[serve_llm] plain vs speculative decode exact-output "
              f"agreement: "
              f"{exact_agree('sp2_4/paged', 'sp2_4/paged+spec'):.2f}")
    return results


if __name__ == "__main__":
    main()
