"""Batched LM serving with SPx-quantized weights: train a small LM briefly
(so the weights are non-random), quantize to the paper's 4-bit SP2, and
serve a batch of requests through the engine — comparing dense vs quantized
weights AND dense vs paged KV layouts (throughput, occupancy, agreement).

  PYTHONPATH=src python examples/serve_llm.py
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data.tokens import TokenStream
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving.engine import Request, ServeEngine
from repro.training import TrainConfig, TrainLoop, make_optimizer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=40)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = reduced(get_config("gemma-2b"), d_model=128, vocab=512)
    rt = Runtime(impl="auto", q_chunk=64)

    # brief training so serving runs on learned weights
    data = TokenStream(cfg.vocab_size, 8, 64, seed=0)
    tc = TrainConfig(max_steps=args.train_steps, log_every=20)
    loop = TrainLoop(lambda p, b: lm_mod.lm_loss(p, b, cfg, rt),
                     make_optimizer("adamw", lr=3e-3),
                     lambda: lm_mod.lm_init(jax.random.PRNGKey(0), cfg),
                     iter(data), tc)
    params, _ = loop.run()
    data.close()

    rng = np.random.default_rng(0)
    # every request opens with the same 16-token system prompt — the
    # prefix-cache axis below shares its KV pages across requests
    sys_prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    prompts = [np.concatenate(
        [sys_prompt,
         rng.integers(0, cfg.vocab_size,
                      int(rng.integers(4, 16))).astype(np.int32)])
        for _ in range(args.requests)]

    results = {}
    # axes: weights (dense vs sp2_4) x KV (dense slots, paged, paged +
    # SPx-quantized codes+scale pages — docs/QUANTIZATION.md) x shared
    # prefix pages x prompt-lookup speculative decoding (docs/SERVING.md)
    for scheme, layout, kvq, share, spec in (
            (None, "dense", False, False, False),
            ("sp2_4", "dense", False, False, False),
            ("sp2_4", "paged", False, False, False),
            ("sp2_4", "paged", True, False, False),
            ("sp2_4", "paged", False, True, False),
            ("sp2_4", "paged", False, False, True)):
        tag = (f"{scheme or 'dense'}/{layout}{'+kvq' if kvq else ''}"
               f"{'+share' if share else ''}{'+spec' if spec else ''}")
        ert = rt.replace(kv_quant=True, kv_scheme="spx_8_x3") if kvq else rt
        # explicit bools (not None) so a REPRO_PREFIX_CACHE=1 /
        # REPRO_SPEC_K environment can't silently flip the other axes
        eng = ServeEngine(params, cfg, batch_slots=4, max_seq=64,
                          quantize=scheme, rt=ert, kv_layout=layout,
                          prefix_cache=share, spec_decode=spec)
        t0 = time.time()
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p,
                               max_new_tokens=args.new_tokens))
        done = eng.run()
        dt = time.time() - t0
        n_tok = sum(len(r.output) for r in done)
        results[tag] = {r.rid: r.output for r in done}
        m = eng.metrics()
        extra = (f" pages {m['n_pages']}x{m['page_size']} "
                 f"occ {m['occupancy_mean']:.2f}"
                 if layout == "paged" else "")
        if share:
            extra += (f" hits {m['prefix_hits']}"
                      f" skipped {m['prefill_tokens_skipped']}tok")
        if spec:
            extra += (f" calls {m['model_calls']}"
                      f" acc {m['draft_acceptance_rate']:.2f}")
        print(f"[serve_llm] {tag:12s}: {n_tok} tokens in {dt:.2f}s "
              f"({n_tok / dt:.0f} tok/s) peak KV "
              f"{m['peak_kv_bytes'] / 2**10:.0f} KiB{extra}")

    # agreement between dense and 4-bit serving (weights axis)
    agree_q = np.mean([
        np.mean(np.array(results["dense/dense"][i])
                == np.array(results["sp2_4/dense"][i]))
        for i in range(args.requests)])
    # agreement between dense-slot and paged KV (layout axis; exact)
    agree_p = np.mean([
        results["sp2_4/dense"][i] == results["sp2_4/paged"][i]
        for i in range(args.requests)])
    # agreement of SPx-quantized KV pages vs the f32 pages (token-level)
    agree_kvq = np.mean([
        np.mean(np.array(results["sp2_4/paged"][i])
                == np.array(results["sp2_4/paged+kvq"][i]))
        for i in range(args.requests)])
    # shared prefix pages vs private pages (layout-internal axis; exact)
    agree_share = np.mean([
        results["sp2_4/paged"][i] == results["sp2_4/paged+share"][i]
        for i in range(args.requests)])
    # speculative decoding vs plain decode (scheduling axis; exact)
    agree_spec = np.mean([
        results["sp2_4/paged"][i] == results["sp2_4/paged+spec"][i]
        for i in range(args.requests)])
    print(f"[serve_llm] dense vs sp2_4 greedy-token agreement: {agree_q:.2f}")
    print(f"[serve_llm] dense vs paged KV exact-output agreement: "
          f"{agree_p:.2f}")
    print(f"[serve_llm] f32 vs SPx-quantized KV pages token agreement: "
          f"{agree_kvq:.2f}")
    print(f"[serve_llm] private vs shared prefix pages exact-output "
          f"agreement: {agree_share:.2f}")
    print(f"[serve_llm] plain vs speculative decode exact-output "
          f"agreement: {agree_spec:.2f}")
    return results


if __name__ == "__main__":
    main()
