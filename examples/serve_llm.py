"""Batched LM serving with SPx-quantized weights: train a small LM briefly
(so the weights are non-random), quantize to the paper's 4-bit SP2, and
serve a batch of requests through the continuous-batching engine, comparing
dense vs quantized outputs and throughput.

  PYTHONPATH=src python examples/serve_llm.py
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data.tokens import TokenStream
from repro.models import lm as lm_mod
from repro.runtime import Runtime
from repro.serving.engine import Request, ServeEngine
from repro.training import TrainConfig, TrainLoop, make_optimizer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=40)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = reduced(get_config("gemma-2b"), d_model=128, vocab=512)
    rt = Runtime(impl="auto", q_chunk=64)

    # brief training so serving runs on learned weights
    data = TokenStream(cfg.vocab_size, 8, 64, seed=0)
    tc = TrainConfig(max_steps=args.train_steps, log_every=20)
    loop = TrainLoop(lambda p, b: lm_mod.lm_loss(p, b, cfg, rt),
                     make_optimizer("adamw", lr=3e-3),
                     lambda: lm_mod.lm_init(jax.random.PRNGKey(0), cfg),
                     iter(data), tc)
    params, _ = loop.run()
    data.close()

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 16)))
               .astype(np.int32) for _ in range(args.requests)]

    results = {}
    for scheme in (None, "sp2_4"):
        eng = ServeEngine(params, cfg, batch_slots=4, max_seq=64,
                          quantize=scheme, rt=rt)
        t0 = time.time()
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p,
                               max_new_tokens=args.new_tokens))
        done = eng.run()
        dt = time.time() - t0
        n_tok = sum(len(r.output) for r in done)
        results[scheme or "dense"] = {r.rid: r.output for r in done}
        print(f"[serve_llm] {scheme or 'dense':6s}: {n_tok} tokens "
              f"in {dt:.2f}s ({n_tok/dt:.0f} tok/s)")

    # agreement between dense and 4-bit serving
    agree = np.mean([
        np.mean(np.array(results["dense"][i])
                == np.array(results["sp2_4"][i]))
        for i in range(args.requests)])
    print(f"[serve_llm] dense vs sp2_4 greedy-token agreement: {agree:.2f}")
    return results


if __name__ == "__main__":
    main()
